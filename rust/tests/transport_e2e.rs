//! Multi-process socket-transport goldens — the `transport_e2e` CI lane.
//!
//! The acceptance property of the transport subsystem: K real OS processes
//! exchanging encoded gradients over loopback sockets produce decoded means
//! **bit-identical** to the in-process simnet collectives at the same seeds.
//! Each test spawns K copies of the `qsgd` binary (`exchange-worker`
//! subcommand), points them at a shared rendezvous address, collects the
//! per-rank decoded means from disk, and compares them f32-bit for f32-bit
//! against `collectives::build(...)` run in this process.
//!
//! Nothing here may hang CI: every socket operation inside the transport is
//! timeout-bounded, the spawner polls children against its own deadline and
//! kills stragglers, and the workflow wraps the whole suite in a hard
//! `timeout`. Per-rank stdout/stderr land under `CARGO_TARGET_TMPDIR` so the
//! CI lane can upload them as artifacts when something fails.

use std::fs::File;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use qsgd::collectives;
use qsgd::config::{CollectiveSpec, ScenarioSpec};
use qsgd::coordinator::CompressorSpec;
use qsgd::simnet::{Link, SimNet, Topology};
use qsgd::transport::{Endpoint, Mesh, MeshConfig};
use qsgd::util::rng::{self, Xoshiro256};

const WORLD: usize = 4;
/// Ragged tail (not a multiple of bucket·K) exercises short final segments.
const N: usize = 3 * 512 * 4 + 37;
const STEPS: usize = 2;
const SEED: u64 = 7;
const GSEED: u64 = 99;
/// Per-test budget for the spawned group; the CI lane's `timeout` wrapper
/// sits above this as a backstop.
const SPAWN_DEADLINE: Duration = Duration::from_secs(120);

fn log_dir(tag: &str) -> PathBuf {
    let d = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("transport_e2e").join(tag);
    std::fs::create_dir_all(&d).expect("creating log dir");
    d
}

/// A free TCP port on loopback: bind :0, read the address, release it.
/// (Racy in principle; rebinding immediately in a child is reliable in
/// practice and the test fails loudly, not flakily silent, if it ever
/// collides.)
fn free_tcp_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("binding probe socket");
    l.local_addr().expect("probe addr").to_string()
}

/// A short UDS base path (the 107-byte sun_path limit rules out
/// CARGO_TARGET_TMPDIR's deep nesting).
#[cfg(unix)]
fn uds_base(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qsgd-e2e-{}-{tag}.sock", std::process::id()))
}

fn golden_mean(
    spec: &CollectiveSpec,
    compressor: &CompressorSpec,
    k: usize,
    n: usize,
    steps: usize,
) -> Vec<f32> {
    golden_mean_scenario(spec, &ScenarioSpec::None, compressor, k, n, steps)
}

fn golden_mean_scenario(
    spec: &CollectiveSpec,
    scenario: &ScenarioSpec,
    compressor: &CompressorSpec,
    k: usize,
    n: usize,
    steps: usize,
) -> Vec<f32> {
    let grads: Vec<Vec<f32>> = (0..k)
        .map(|w| rng::normal_vec(&mut Xoshiro256::stream(GSEED, w as u64), n))
        .collect();
    let net = SimNet::new(k, Link::new(1e9, 1e-6), Topology::P2pBroadcast);
    let mut algo = collectives::build_with_scenario(spec, scenario, compressor.codec(), k, SEED)
        .expect("in-process golden algo");
    algo.prepare(n);
    let mut mean = Vec::new();
    for _ in 0..steps {
        algo.exchange(&net, &grads, &mut mean).expect("in-process golden exchange");
    }
    mean
}

fn read_mean(path: &PathBuf) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
    assert_eq!(bytes.len() % 4, 0, "mean file {path:?} is not f32-aligned");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn tail_of(path: &PathBuf) -> String {
    let s = std::fs::read_to_string(path).unwrap_or_default();
    let lines: Vec<&str> = s.lines().rev().take(12).collect();
    lines.into_iter().rev().collect::<Vec<_>>().join("\n")
}

/// Spawn K `exchange-worker` ranks against `transport`, wait for all of
/// them under a deadline, and return the per-rank decoded means.
fn run_group(tag: &str, transport: &str, collective: &str, compressor: &str) -> Vec<Vec<f32>> {
    run_group_with(tag, transport, collective, compressor, &|_| Vec::new(), &[])
        .into_iter()
        .map(|m| m.expect("all ranks succeed"))
        .collect()
}

/// Like [`run_group`], with per-rank extra CLI args and a set of ranks
/// *expected* to exit with an error (churn injection). Returns `None` for
/// the ranks in `expect_fail` — their mean file is never written.
fn run_group_with(
    tag: &str,
    transport: &str,
    collective: &str,
    compressor: &str,
    extra: &dyn Fn(usize) -> Vec<String>,
    expect_fail: &[usize],
) -> Vec<Option<Vec<f32>>> {
    let dir = log_dir(tag);
    let mut children: Vec<Child> = Vec::with_capacity(WORLD);
    let mut mean_paths = Vec::with_capacity(WORLD);
    for r in 0..WORLD {
        let out = dir.join(format!("rank{r}.mean"));
        let stdout = File::create(dir.join(format!("rank{r}.out"))).expect("rank stdout log");
        let stderr = File::create(dir.join(format!("rank{r}.err"))).expect("rank stderr log");
        let child = Command::new(env!("CARGO_BIN_EXE_qsgd"))
            .args([
                "exchange-worker",
                "--transport",
                transport,
                "--rank",
                &r.to_string(),
                "--world",
                &WORLD.to_string(),
                "--collective",
                collective,
                "--compressor",
                compressor,
                "--n",
                &N.to_string(),
                "--steps",
                &STEPS.to_string(),
                "--seed",
                &SEED.to_string(),
                "--gseed",
                &GSEED.to_string(),
                "--out",
                out.to_str().expect("utf-8 tmpdir"),
                "--io-timeout-ms",
                "20000",
                "--connect-timeout-ms",
                "30000",
            ])
            .args(extra(r))
            .stdout(Stdio::from(stdout))
            .stderr(Stdio::from(stderr))
            .spawn()
            .unwrap_or_else(|e| panic!("{tag}: spawning rank {r}: {e}"));
        children.push(child);
        mean_paths.push(out);
    }

    let deadline = Instant::now() + SPAWN_DEADLINE;
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; WORLD];
    loop {
        let mut pending = false;
        for (r, ch) in children.iter_mut().enumerate() {
            if statuses[r].is_none() {
                match ch.try_wait().expect("try_wait") {
                    Some(st) => statuses[r] = Some(st),
                    None => pending = true,
                }
            }
        }
        if !pending {
            break;
        }
        if Instant::now() >= deadline {
            for ch in children.iter_mut() {
                let _ = ch.kill();
            }
            let tails: Vec<String> = (0..WORLD)
                .map(|r| format!("-- rank {r} --\n{}", tail_of(&dir.join(format!("rank{r}.err")))))
                .collect();
            panic!(
                "{tag}: worker group did not finish within {SPAWN_DEADLINE:?}\n{}",
                tails.join("\n")
            );
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    for (r, st) in statuses.iter().enumerate() {
        let st = st.expect("filled");
        if expect_fail.contains(&r) {
            assert!(
                !st.success(),
                "{tag}: rank {r} was expected to die (churn injection) but exited cleanly"
            );
        } else {
            assert!(
                st.success(),
                "{tag}: rank {r} exited with {st}\nstderr tail:\n{}",
                tail_of(&dir.join(format!("rank{r}.err")))
            );
        }
    }
    mean_paths
        .iter()
        .enumerate()
        .map(|(r, p)| if expect_fail.contains(&r) { None } else { Some(read_mean(p)) })
        .collect()
}

fn assert_bit_identical(tag: &str, got: &[Vec<f32>], want: &[f32]) {
    assert!(want.iter().any(|&x| x != 0.0), "{tag}: golden mean is all zeros");
    for (r, mean) in got.iter().enumerate() {
        assert_eq!(mean.len(), want.len(), "{tag}: rank {r} mean length");
        for (i, (a, b)) in mean.iter().zip(want).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{tag}: rank {r} diverges from the in-process golden at coord {i}: \
                 {a} ({:#010x}) vs {b} ({:#010x})",
                a.to_bits(),
                b.to_bits()
            );
        }
    }
}

fn check_arm(tag: &str, transport: &str, collective: &str, compressor: &str) {
    let spec = CollectiveSpec::parse(collective).unwrap();
    let comp = CompressorSpec::parse(compressor).unwrap();
    let want = golden_mean(&spec, &comp, WORLD, N, STEPS);
    let got = run_group(tag, transport, collective, compressor);
    assert_bit_identical(tag, &got, &want);
}

// ---------------------------------------------------------------------------
// Acceptance goldens: K=4 real processes ≡ in-process, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn tcp_a2a_matches_inprocess_golden_uniform_and_nonuniform() {
    check_arm("tcp-a2a-qsgd4", &format!("tcp:{}", free_tcp_addr()), "a2a", "qsgd4");
    check_arm("tcp-a2a-nuqsgd4", &format!("tcp:{}", free_tcp_addr()), "a2a", "nuqsgd4");
}

#[test]
fn tcp_ring_matches_inprocess_golden_uniform_and_nonuniform() {
    check_arm("tcp-ring-qsgd4", &format!("tcp:{}", free_tcp_addr()), "ring", "qsgd4");
    check_arm("tcp-ring-nuqsgd4", &format!("tcp:{}", free_tcp_addr()), "ring", "nuqsgd4");
}

#[test]
fn tcp_ring_ef_and_raw_match_inprocess_golden() {
    check_arm("tcp-ring-ef", &format!("tcp:{}", free_tcp_addr()), "ring:ef", "qsgd4");
    check_arm("tcp-ring-raw", &format!("tcp:{}", free_tcp_addr()), "ring:raw", "qsgd4");
}

#[test]
fn tcp_hier_matches_inprocess_golden() {
    check_arm("tcp-hier2", &format!("tcp:{}", free_tcp_addr()), "hier:2", "qsgd4");
    // group ≥ world degenerates to one fan-in group + a 1-member leader ring
    check_arm("tcp-hier8", &format!("tcp:{}", free_tcp_addr()), "hier:8", "qsgd4");
}

#[cfg(unix)]
#[test]
fn uds_a2a_and_ring_match_inprocess_golden() {
    for (tag, col) in [("uds-a2a", "a2a"), ("uds-ring", "ring")] {
        let base = uds_base(tag);
        let transport = format!("uds:{}", base.display());
        check_arm(tag, &transport, col, "qsgd4");
        qsgd::transport::net::cleanup_uds(&base, WORLD);
    }
}

// ---------------------------------------------------------------------------
// Pipelined exchange (--overlap on): same bits as the serial goldens
// ---------------------------------------------------------------------------

/// Like [`check_arm`] but with the pipelined exchange paths enabled. The
/// golden is the *same* in-process serial mean: decode-on-arrival and the
/// writer-thread ring hops must not change a single bit.
fn check_arm_overlap(tag: &str, transport: &str, collective: &str, compressor: &str) {
    let spec = CollectiveSpec::parse(collective).unwrap();
    let comp = CompressorSpec::parse(compressor).unwrap();
    let want = golden_mean(&spec, &comp, WORLD, N, STEPS);
    let extra = |_: usize| vec!["--overlap".to_string(), "on".to_string()];
    let got: Vec<Vec<f32>> =
        run_group_with(tag, transport, collective, compressor, &extra, &[])
            .into_iter()
            .flatten()
            .collect();
    assert_eq!(got.len(), WORLD);
    assert_bit_identical(tag, &got, &want);
}

#[test]
fn tcp_overlap_a2a_matches_serial_golden() {
    check_arm_overlap("tcp-ov-a2a-qsgd4", &format!("tcp:{}", free_tcp_addr()), "a2a", "qsgd4");
    check_arm_overlap(
        "tcp-ov-a2a-nuqsgd4",
        &format!("tcp:{}", free_tcp_addr()),
        "a2a",
        "nuqsgd4",
    );
}

#[test]
fn tcp_overlap_ring_matches_serial_golden() {
    check_arm_overlap("tcp-ov-ring-qsgd4", &format!("tcp:{}", free_tcp_addr()), "ring", "qsgd4");
    check_arm_overlap(
        "tcp-ov-ring-nuqsgd4",
        &format!("tcp:{}", free_tcp_addr()),
        "ring",
        "nuqsgd4",
    );
}

#[test]
fn tcp_overlap_ring_ef_matches_serial_golden() {
    // Error-feedback residuals persist across hops and steps; pipelining
    // must leave the residual trajectory untouched too.
    check_arm_overlap("tcp-ov-ring-ef-qsgd4", &format!("tcp:{}", free_tcp_addr()), "ring:ef", "qsgd4");
    check_arm_overlap(
        "tcp-ov-ring-ef-nuqsgd4",
        &format!("tcp:{}", free_tcp_addr()),
        "ring:ef",
        "nuqsgd4",
    );
}

// ---------------------------------------------------------------------------
// Observability: --trace-out across real processes
// ---------------------------------------------------------------------------

#[test]
fn tcp_a2a_trace_out_emits_valid_per_rank_artifacts() {
    // `--trace-out` across K real processes: every rank exports a Chrome
    // trace and a JSONL span log into the shared directory, and tracing
    // must not perturb the exchanged bits (same golden as the untraced
    // arm). The CI lane runs scripts/check_trace.py over this directory
    // afterwards, so the file names here are load-bearing.
    let tag = "tcp-a2a-trace";
    let dir = log_dir(tag);
    let spec = CollectiveSpec::parse("a2a").unwrap();
    let comp = CompressorSpec::parse("qsgd4").unwrap();
    let want = golden_mean(&spec, &comp, WORLD, N, STEPS);
    let trace_dir = dir.to_str().expect("utf-8 tmpdir").to_string();
    let extra = move |_: usize| vec!["--trace-out".to_string(), trace_dir.clone()];
    let got: Vec<Vec<f32>> =
        run_group_with(tag, &format!("tcp:{}", free_tcp_addr()), "a2a", "qsgd4", &extra, &[])
            .into_iter()
            .flatten()
            .collect();
    assert_bit_identical(tag, &got, &want);
    for r in 0..WORLD {
        for name in [format!("trace_rank{r}.json"), format!("events_rank{r}.jsonl")] {
            let p = dir.join(&name);
            let len = std::fs::metadata(&p)
                .unwrap_or_else(|e| panic!("{tag}: missing {name}: {e}"))
                .len();
            assert!(len > 2, "{tag}: {name} is empty");
        }
    }
}

// ---------------------------------------------------------------------------
// Churn and corruption: the recovery protocol across real processes
// ---------------------------------------------------------------------------

#[test]
fn tcp_a2a_churn_killed_rank_renormalizes_without_hanging() {
    // The CI lane's churn case. Rank 3 dies at the top of step 1 — before
    // sending anything, so every survivor times it out in the same round.
    // Survivors must (a) never hang (io timeouts bound the stall, the
    // group deadline and the lane's `timeout` back that up) and (b) finish
    // the epoch with means renormalized over {0,1,2}, bit-identical to the
    // in-process `drop:3@1` golden.
    let spec = CollectiveSpec::parse("a2a").unwrap();
    let comp = CompressorSpec::parse("qsgd4").unwrap();
    let want = golden_mean_scenario(
        &spec,
        &ScenarioSpec::Drop { rank: 3, step: 1 },
        &comp,
        WORLD,
        N,
        STEPS,
    );
    let dir = log_dir("tcp-a2a-churn");
    let trace_dir = dir.to_str().expect("utf-8 tmpdir").to_string();
    let extra = move |r: usize| -> Vec<String> {
        let mut v = vec!["--recover".to_string(), "--trace-out".to_string(), trace_dir.clone()];
        if r == 3 {
            v.extend(["--die-at-step".to_string(), "1".to_string()]);
        }
        v
    };
    let got = run_group_with(
        "tcp-a2a-churn",
        &format!("tcp:{}", free_tcp_addr()),
        "a2a",
        "qsgd4",
        &extra,
        &[3],
    );
    let survivors: Vec<Vec<f32>> = got.into_iter().flatten().collect();
    assert_eq!(survivors.len(), WORLD - 1);
    assert_bit_identical("tcp-a2a-churn", &survivors, &want);

    // Every rank leaves a non-empty flight-recorder dump: rank 3 from the
    // fatal-error path, the survivors from the dead-worker recovery dump.
    for r in 0..WORLD {
        let flight = dir.join(format!("flight_rank{r}.txt"));
        let text = std::fs::read_to_string(&flight)
            .unwrap_or_else(|e| panic!("tcp-a2a-churn: missing {}: {e}", flight.display()));
        assert!(
            text.contains("flight recorder dump"),
            "tcp-a2a-churn: rank {r} dump header missing:\n{text}"
        );
        assert!(
            text.lines().count() > 2,
            "tcp-a2a-churn: rank {r} flight dump has no crumbs:\n{text}"
        );
    }
}

#[test]
fn tcp_a2a_corrupt_frames_recover_to_fault_free_golden() {
    // Seeded sender-side corruption across real processes: recovery
    // re-requests the damaged frames, and the repaired run is bit-identical
    // to the fault-free golden (resends carry the original bytes).
    let spec = CollectiveSpec::parse("a2a").unwrap();
    let comp = CompressorSpec::parse("qsgd4").unwrap();
    let want = golden_mean(&spec, &comp, WORLD, N, STEPS);
    let extra = |r: usize| -> Vec<String> {
        let mut v = vec!["--recover".to_string()];
        if r == 1 {
            v.extend([
                "--corrupt-prob".to_string(),
                "1.0".to_string(),
                "--max-faults".to_string(),
                "2".to_string(),
            ]);
        }
        v
    };
    let got = run_group_with(
        "tcp-a2a-corrupt",
        &format!("tcp:{}", free_tcp_addr()),
        "a2a",
        "qsgd4",
        &extra,
        &[],
    );
    let means: Vec<Vec<f32>> = got.into_iter().flatten().collect();
    assert_bit_identical("tcp-a2a-corrupt", &means, &want);
}

#[test]
fn tcp_a2a_overlap_with_recovery_falls_back_serial_and_recovers() {
    // `--overlap on --recover` together: recovery needs the serial re-request
    // protocol, so the exchange transparently ignores the pipelined paths.
    // The run must still repair rank 1's corrupted frames down to the
    // fault-free golden bits — proving the fallback really is the serial path.
    let spec = CollectiveSpec::parse("a2a").unwrap();
    let comp = CompressorSpec::parse("qsgd4").unwrap();
    let want = golden_mean(&spec, &comp, WORLD, N, STEPS);
    let extra = |r: usize| -> Vec<String> {
        let mut v = vec!["--recover".to_string(), "--overlap".to_string(), "on".to_string()];
        if r == 1 {
            v.extend([
                "--corrupt-prob".to_string(),
                "1.0".to_string(),
                "--max-faults".to_string(),
                "2".to_string(),
            ]);
        }
        v
    };
    let got = run_group_with(
        "tcp-ov-a2a-corrupt",
        &format!("tcp:{}", free_tcp_addr()),
        "a2a",
        "qsgd4",
        &extra,
        &[],
    );
    let means: Vec<Vec<f32>> = got.into_iter().flatten().collect();
    assert_bit_identical("tcp-ov-a2a-corrupt", &means, &want);
}

// ---------------------------------------------------------------------------
// Single-process mesh + end-to-end launcher
// ---------------------------------------------------------------------------

#[test]
fn world_of_one_needs_no_sockets() {
    use qsgd::transport::SocketExchange;
    let mesh = Mesh::connect(
        &Endpoint::Tcp("127.0.0.1:0".into()),
        &MeshConfig {
            rank: 0,
            world: 1,
            io_timeout: Duration::from_secs(1),
            connect_timeout: Duration::from_secs(1),
        },
    )
    .expect("world=1 mesh");
    let spec = CollectiveSpec::parse("ring").unwrap();
    let mut ex =
        SocketExchange::new(&spec, CompressorSpec::qsgd_4bit().codec(), mesh, SEED).unwrap();
    let grad = rng::normal_vec(&mut Xoshiro256::stream(GSEED, 0), 700);
    let mut mean = Vec::new();
    ex.exchange(&grad, &mut mean).expect("degenerate exchange");
    let want = golden_mean(&spec, &CompressorSpec::qsgd_4bit(), 1, 700, 1);
    assert_bit_identical("world1-ring", &[mean], &want);
}

#[test]
fn train_launcher_spawns_ranks_and_succeeds() {
    // The user-facing path: `qsgd train --transport tcp:…` with no --rank
    // spawns the whole group and aggregates exit codes.
    let dir = log_dir("train-launcher");
    let stdout = File::create(dir.join("launcher.out")).unwrap();
    let stderr = File::create(dir.join("launcher.err")).unwrap();
    let st = Command::new(env!("CARGO_BIN_EXE_qsgd"))
        .args([
            "train",
            "--model",
            "quadratic",
            "--compressor",
            "qsgd4",
            "--collective",
            "ring",
            "--workers",
            "2",
            "--steps",
            "5",
            "--lr",
            "0.05",
            "--transport",
            &format!("tcp:{}", free_tcp_addr()),
            "--spawn-timeout-s",
            "100",
        ])
        .stdout(Stdio::from(stdout))
        .stderr(Stdio::from(stderr))
        .status()
        .expect("running train launcher");
    assert!(
        st.success(),
        "train launcher failed ({st})\nstderr tail:\n{}",
        tail_of(&dir.join("launcher.err"))
    );
    let out = std::fs::read_to_string(dir.join("launcher.out")).unwrap_or_default();
    assert!(out.contains("wall:"), "launcher output missing wall-clock line:\n{out}");
}

// ---------------------------------------------------------------------------
// Failure modes: dead or silent peers surface as clean errors, never hangs
// ---------------------------------------------------------------------------

fn two_rank_cfg(rank: usize, io_ms: u64) -> MeshConfig {
    MeshConfig {
        rank,
        world: 2,
        io_timeout: Duration::from_millis(io_ms),
        connect_timeout: Duration::from_secs(20),
    }
}

#[test]
fn peer_disconnect_mid_hop_is_a_clean_error() {
    let base = Endpoint::Tcp(free_tcp_addr());
    let b2 = base.clone();
    let peer = std::thread::spawn(move || {
        // Rank 1 joins the mesh, then drops it without sending anything.
        let mesh = Mesh::connect(&b2, &two_rank_cfg(1, 5_000)).expect("rank 1 mesh");
        drop(mesh);
    });
    let mut mesh = Mesh::connect(&base, &two_rank_cfg(0, 5_000)).expect("rank 0 mesh");
    let t0 = Instant::now();
    let err = mesh.recv_from(1).expect_err("recv from a closed peer must fail");
    assert!(t0.elapsed() < Duration::from_secs(10), "disconnect detection took too long");
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1"), "error should name the peer: {msg}");
    peer.join().expect("peer thread");
}

#[test]
fn silent_peer_times_out_instead_of_hanging() {
    let base = Endpoint::Tcp(free_tcp_addr());
    let b2 = base.clone();
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    let peer = std::thread::spawn(move || {
        // Rank 1 connects, then sits silent (alive, sending nothing) until
        // rank 0 has observed its read timeout.
        let mesh = Mesh::connect(&b2, &two_rank_cfg(1, 10_000)).expect("rank 1 mesh");
        let _ = release_rx.recv_timeout(Duration::from_secs(30));
        drop(mesh);
    });
    let mut mesh = Mesh::connect(&base, &two_rank_cfg(0, 300)).expect("rank 0 mesh");
    let t0 = Instant::now();
    let err = mesh.recv_from(1).expect_err("read from a silent peer must time out");
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(250) && waited < Duration::from_secs(10),
        "timeout fired after {waited:?}, configured 300ms"
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1"), "error should name the peer: {msg}");
    release_tx.send(()).ok();
    peer.join().expect("peer thread");
}

#[test]
fn send_recv_survives_two_rank_ring_traffic() {
    // The to == from send_recv path (2-rank ring): both sides exchange
    // concurrently through the split read/write halves of one socket.
    let base = Endpoint::Tcp(free_tcp_addr());
    let b2 = base.clone();
    let peer = std::thread::spawn(move || -> Vec<u8> {
        let mut mesh = Mesh::connect(&b2, &two_rank_cfg(1, 10_000)).expect("rank 1 mesh");
        let payload = vec![1u8; 200_000];
        let got = mesh.send_recv(0, 0, &payload).expect("rank 1 hop");
        got.to_vec()
    });
    let mut mesh = Mesh::connect(&base, &two_rank_cfg(0, 10_000)).expect("rank 0 mesh");
    let payload = vec![2u8; 200_000];
    let got = mesh.send_recv(1, 1, &payload).expect("rank 0 hop").to_vec();
    let peer_got = peer.join().expect("peer thread");
    assert_eq!(got, vec![1u8; 200_000]);
    assert_eq!(peer_got, vec![2u8; 200_000]);
}
