//! Round-trip + message-size properties for the baseline compressors that
//! predate the property harness: TernGrad, 1BitSGD (including the
//! error-feedback residual across steps) and the deterministic Appendix-F
//! top-k quantizer. Each advertises an exact `message_bits` — the cost
//! models in `models::cost`/`simnet` rely on it, so it must match the real
//! encoded length.

mod common;

use qsgd::prop_assert;
use qsgd::quant::deterministic;
use qsgd::quant::onebit::OneBitSgd;
use qsgd::quant::terngrad::TernGrad;
use qsgd::util::check::forall;

#[test]
fn prop_terngrad_roundtrip_and_message_size() {
    forall("terngrad", 120, 2000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let bucket = [1usize, 16, 64, 512][g.usize_in(0, 3)];
        let t = TernGrad::new(bucket);
        let msg = t.compress(&v, g.rng);
        prop_assert!(
            msg.len() as u64 == t.message_bits(n).div_ceil(8),
            "message_bits {} disagrees with encoded length {}",
            t.message_bits(n),
            msg.len()
        );
        let d = t.decompress(&msg, n).map_err(|e| e.to_string())?;
        prop_assert!(d.len() == n, "length");
        // every reconstruction is ternary on the bucket scale
        for (cb, cv) in d.chunks(bucket).zip(v.chunks(bucket)) {
            let scale = cv.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for &y in cb {
                prop_assert!(
                    y == 0.0 || (y.abs() - scale).abs() <= scale * 1e-6,
                    "non-ternary value {y} (scale {scale})"
                );
            }
        }
        // truncated messages must be rejected, not mis-decoded
        if msg.len() > 4 {
            prop_assert!(
                t.decompress(&msg[..msg.len() / 2], n).is_err(),
                "truncated terngrad message decoded"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_onebit_roundtrip_and_message_size() {
    forall("onebit", 100, 1500, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let column = [1usize, 32, 512][g.usize_in(0, 2)];
        let mut q = OneBitSgd::new(n, column);
        // several steps so the error-feedback residual is in play
        let mut prev_residual = vec![0.0f32; n];
        for step in 0..3 {
            // clamp extremes: the delta-sigma bookkeeping below is only
            // numerically meaningful while sums stay inside f32 range
            let v: Vec<f32> =
                common::gen_vec(g, n).iter().map(|x| x.clamp(-1e30, 1e30)).collect();
            let msg = q.compress(&v);
            prop_assert!(
                msg.len() as u64 == OneBitSgd::message_bits(n, column).div_ceil(8),
                "step {step}: message_bits disagrees with encoded length"
            );
            let d = OneBitSgd::decompress(&msg, n, column).map_err(|e| e.to_string())?;
            prop_assert!(d.len() == n, "length");
            // delta-sigma invariant: decoded + new residual == grad + old
            // residual (no gradient mass lost), coordinate-wise
            for i in 0..n {
                let eff = v[i] + prev_residual[i];
                let got = d[i] + q.residual()[i];
                // magnitude-aware tolerance: `eff − recon` cancels
                // catastrophically when the column mixes magnitudes
                let tol = 1e-3 * (eff.abs() + d[i].abs()).max(1.0);
                prop_assert!(
                    (got - eff).abs() <= tol,
                    "step {step}: mass lost at {i}: {got} vs {eff}"
                );
            }
            prev_residual.copy_from_slice(q.residual());
        }
        // reset clears the carried state
        q.reset();
        prop_assert!(q.residual().iter().all(|&r| r == 0.0), "reset left residual");
        Ok(())
    });
}

#[test]
fn onebit_residual_carries_across_steps() {
    // A coordinate too small to flip its column's sign on step one must be
    // transmitted eventually — and the residual is what carries it.
    let mut q = OneBitSgd::new(4, 4);
    let g = [2.0f32, 0.05, -2.0, -0.05];
    let first = q.compress(&g);
    let d1 = OneBitSgd::decompress(&first, 4, 4).unwrap();
    // second step sees grad + residual, so its message differs
    let second = q.compress(&g);
    let d2 = OneBitSgd::decompress(&second, 4, 4).unwrap();
    let mean1: f32 = (d1[1] + d2[1]) / 2.0;
    // two-step average of the small positive coordinate moves toward 0.05
    assert!(
        (mean1 - 0.05).abs() < (d1[1] - 0.05).abs() + 1e-6,
        "error feedback did not pull the small coordinate toward its value"
    );
    // stateless decompress: same message decodes identically twice
    assert_eq!(OneBitSgd::decompress(&first, 4, 4).unwrap(), d1);
}

#[test]
fn prop_topk_roundtrip_and_message_size() {
    forall("topk", 120, 2000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        // the Appendix-F quantizer is defined for finite inputs
        let v: Vec<f32> = v.iter().map(|x| x.clamp(-1e30, 1e30)).collect();
        let q = deterministic::quantize(&v);
        let bytes = q.encode();
        prop_assert!(
            bytes.len() as u64 == q.message_bits().div_ceil(8),
            "message_bits {} disagrees with encoded length {}",
            q.message_bits(),
            bytes.len()
        );
        let q2 = deterministic::TopQuantized::decode(&bytes, n).map_err(|e| e.to_string())?;
        prop_assert!(q2 == q, "roundtrip mismatch");
        // truncation is rejected
        if bytes.len() > 5 && !q.indices.is_empty() {
            prop_assert!(
                deterministic::TopQuantized::decode(&bytes[..bytes.len() / 2], n).is_err(),
                "truncated top-k message decoded"
            );
        }
        Ok(())
    });
}
