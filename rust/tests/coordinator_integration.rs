//! Cross-module integration tests: the training loops composed with real
//! codecs over the simulated interconnect, including failure injection and
//! the invariants the paper's Algorithm 1 relies on.

use qsgd::coordinator::sources::{ConvexSource, GradSource};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::{async_ps, svrg, CompressorSpec};
use qsgd::data::{LogisticProblem, QuadraticProblem};
use qsgd::models::layout::{ParamLayout, QuantPlan};
use qsgd::models::CostModel;
use qsgd::simnet::{Link, SimNet, Topology};

fn quad_source(seed: u64) -> ConvexSource<QuadraticProblem> {
    ConvexSource::new(QuadraticProblem::generate(512, 192, 1e-3, 0.1, seed), 8, seed)
}

#[test]
fn all_compressor_arms_reach_similar_loss() {
    // Fig. 3-style parity at equal step count on a convex objective.
    let arms = [
        CompressorSpec::Fp32,
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::OneBit { column: 512 },
        CompressorSpec::TernGrad { bucket: 64 },
    ];
    let mut finals = Vec::new();
    for spec in arms {
        let mut src = quad_source(1);
        let cfg = SyncConfig::quick(4, 250, spec, 0.04);
        let res = SyncTrainer::new(cfg).run(&mut src).unwrap();
        finals.push((res.label, res.loss.tail_mean(3)));
    }
    let fp32 = finals[0].1;
    for (label, l) in &finals[1..] {
        assert!(
            *l < fp32 * 3.0 + 0.05,
            "{label} diverged: {l} vs fp32 {fp32} ({finals:?})"
        );
    }
}

#[test]
fn skip_rule_plan_composes_with_training() {
    // A model whose layout mixes tiny (fp32) and large (quantized) tensors
    // must train under the paper-default plan.
    // Same structure as the paper's rule, scaled down (threshold 500 in
    // place of 10K so the test stays fast): small tensors ride fp32.
    let layout = ParamLayout::synthetic(&[
        ("emb", vec![4, 100]),  // 400 < 500 ⇒ fp32
        ("w1", vec![8, 150]),   // 1200 ⇒ quantized
        ("b1", vec![50]),       // fp32
    ]);
    let n = layout.total_params();
    let plan = QuantPlan::build(&layout, 500);
    assert!(plan.quantized_fraction() > 0.5 && plan.quantized_fraction() < 1.0);

    let p = QuadraticProblem::generate(2048, n, 1e-3, 0.1, 3);
    let mut src = ConvexSource::new(p, 32, 3);
    let mut cfg = SyncConfig::quick(4, 400, CompressorSpec::qsgd_4bit(), 0.05);
    cfg.plan = Some(plan);
    let res = SyncTrainer::new(cfg).run(&mut src).unwrap();
    assert!(res.loss.tail_mean(2) < res.loss.points[0].1 * 0.6);
    // wire must be below fp32 but above the fully-quantized floor
    let bits = res.wire.bits_per_coordinate();
    assert!(bits > 3.0 && bits < 32.0, "bits/coord {bits}");
}

#[test]
fn corrupted_peer_message_fails_loudly() {
    // Decode of a tampered message must error, not silently produce junk.
    use qsgd::coordinator::exchange::PlanCodec;
    use qsgd::quant::{Codec, EncodeSession};
    use qsgd::util::rng::{self, Xoshiro256};
    let layout = ParamLayout::synthetic(&[("w", vec![5000])]);
    let plan = QuantPlan::quantize_all(&layout);
    let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
    let mut rng = Xoshiro256::from_u64(0);
    let grad = rng::normal_vec(&mut rng, 5000);
    let msg = pc.session(Xoshiro256::from_u64(1)).compress(&grad);
    for cut in [0usize, 1, msg.len() / 2, msg.len() - 1] {
        assert!(pc.decode(&msg[..cut], 5000).is_err(), "truncation at {cut} accepted");
    }
    let mut flipped = msg.clone();
    flipped[4] ^= 0xff; // clobber the first segment header
    assert!(pc.decode(&flipped, 5000).is_err() || pc.decode(&flipped, 5000).is_ok());
    // (bit flips inside Elias payloads may decode to *different valid*
    // levels — entropy codes are not error-detecting; the frame-level
    // length checks are what must hold:)
    let mut extended = msg.clone();
    extended.push(0);
    assert!(pc.decode(&extended, 5000).is_err(), "trailing bytes accepted");
}

#[test]
fn async_and_sync_agree_in_the_limit() {
    // With 1 worker the async parameter server degenerates to sequential
    // SGD; it must reach a loss comparable to the sync trainer's.
    let mut src_async = quad_source(5);
    let cfg = async_ps::AsyncConfig {
        workers: 1,
        updates: 200,
        compressor: CompressorSpec::qsgd_4bit(),
        lr: 0.04,
        seed: 5,
        net: SimNet::new(1, Link::new(1e9, 1e-6), Topology::Star),
        cost: CostModel::k80(),
        speed: vec![],
        log_every: 20,
    };
    let ra = async_ps::run(&cfg, &mut src_async).unwrap();
    let mut src_sync = quad_source(5);
    let rs = SyncTrainer::new(SyncConfig::quick(1, 200, CompressorSpec::qsgd_4bit(), 0.04))
        .run(&mut src_sync)
        .unwrap();
    assert_eq!(ra.max_staleness, 0, "single worker cannot be stale");
    let (la, ls) = (ra.loss.tail_mean(3), rs.loss.tail_mean(3));
    assert!(la < ls * 3.0 + 0.05, "async {la} vs sync {ls}");
}

#[test]
fn svrg_beats_sgd_at_equal_gradient_budget() {
    let obj = LogisticProblem::generate(256, 96, 0.05, 9);
    let f_star = svrg::solve_f_star(&obj, 4000);
    let cfg = svrg::SvrgConfig {
        processors: 4,
        epochs: 4,
        iters: None, // Theorem 3.6's T = O(L/ℓ)
        eta: None,
        seed: 9,
        quantize: true,
    };
    let rq = svrg::run(&cfg, &obj, f_star).unwrap();
    let p2 = LogisticProblem::generate(256, 96, 0.05, 9);
    let mut src = ConvexSource::new(p2, 2, 10);
    let res = SyncTrainer::new(SyncConfig::quick(4, 360, CompressorSpec::qsgd_4bit(), 0.05))
        .run(&mut src)
        .unwrap();
    let sgd_gap = res.loss.tail_mean(2) - f_star;
    assert!(
        rq.gap.last().unwrap() < sgd_gap * 0.5,
        "QSVRG {:?} should beat QSGD {sgd_gap}",
        rq.gap.last()
    );
}

#[test]
fn zero_and_constant_gradients_survive_the_full_path() {
    // Degenerate gradients (all-zero, all-equal) must round-trip the whole
    // encode→broadcast→decode→update pipeline without NaNs.
    struct DegenerateSource {
        n: usize,
        mode: u8,
    }
    impl GradSource for DegenerateSource {
        fn dim(&self) -> usize {
            self.n
        }
        fn loss_and_grad(&mut self, _w: usize, step: u64, _p: &[f32]) -> anyhow::Result<(f32, Vec<f32>)> {
            let g = match (self.mode + step as u8) % 3 {
                0 => vec![0.0; self.n],
                1 => vec![1.0; self.n],
                _ => vec![-1e-30; self.n], // denormal territory
            };
            Ok((0.0, g))
        }
        fn flops_fwd_per_step(&self) -> f64 {
            1.0
        }
        fn name(&self) -> String {
            "degenerate".into()
        }
    }
    for spec in [CompressorSpec::qsgd_4bit(), CompressorSpec::OneBit { column: 64 }] {
        let mut src = DegenerateSource { n: 1000, mode: 0 };
        let res = SyncTrainer::new(SyncConfig::quick(3, 9, spec, 0.1)).run(&mut src).unwrap();
        assert!(res.params.iter().all(|p| p.is_finite()), "non-finite params");
    }
}
