//! Integration tests over the PJRT runtime and the AOT artifacts — the
//! Rust ⇄ JAX contract. Require `make artifacts`; each test is skipped
//! (with a notice) when the artifacts directory is absent so `cargo test`
//! stays green on a fresh checkout.

use qsgd::coordinator::sources::{GradSource, RuntimeSource, Workload};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::{ClassifyData, TokenCorpus};
use qsgd::models::layout::QuantPlan;
use qsgd::runtime::{artifact, Input, Runtime};
use qsgd::util::rng::{self, Xoshiro256};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifact::default_dir().join("manifest.json").exists() {
        eprintln!("[skipped: run `make artifacts` first]");
        return None;
    }
    Some(Runtime::from_default_dir().expect("runtime init"))
}

#[test]
fn manifest_covers_all_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["logreg_grad", "mlp_grad", "mlp_grad_q", "tfm_grad", "tfm_grad_q", "quantize"] {
        let a = rt.manifest().get(name).unwrap();
        assert!(a.path.exists(), "{name} HLO file missing");
        assert!(!a.inputs.is_empty() && !a.outputs.is_empty());
    }
}

#[test]
fn logreg_gradient_matches_finite_differences() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("logreg_grad").unwrap().clone();
    let n = art.params.unwrap();
    let dim = art.inputs[1].shape[1];
    let batch = art.batch.unwrap();

    let mut rng = Xoshiro256::from_u64(0);
    let params: Vec<f32> = rng::normal_vec(&mut rng, n).iter().map(|x| x * 0.2).collect();
    let x = rng::normal_vec(&mut rng, batch * dim);
    let y: Vec<f32> = (0..batch).map(|_| (rng::uniform_f32(&mut rng) > 0.5) as u8 as f32).collect();
    let xs = [batch, dim];
    let ys = [batch];
    let inputs = [Input::F32(&x, &xs), Input::F32(&y, &ys)];

    let (loss, grad) = rt.grad("logreg_grad", &params, &inputs).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(grad.len(), n);

    // central differences on a few coordinates
    let eps = 1e-2f32;
    for j in [0usize, 1, n / 2, n - 1] {
        let mut pp = params.clone();
        let mut pm = params.clone();
        pp[j] += eps;
        pm[j] -= eps;
        let (lp, _) = rt.grad("logreg_grad", &pp, &inputs).unwrap();
        let (lm, _) = rt.grad("logreg_grad", &pm, &inputs).unwrap();
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - grad[j]).abs() < 2e-2 + 0.05 * grad[j].abs(),
            "coord {j}: fd {fd} vs grad {}",
            grad[j]
        );
    }
}

#[test]
fn fused_quantized_gradient_is_on_grid_and_loss_matches() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("mlp_grad_q").unwrap().clone();
    let q = art.quant.unwrap();
    let n = art.params.unwrap();
    let dim = art.inputs[2].shape[1];
    let batch = art.batch.unwrap();

    let mut rng = Xoshiro256::from_u64(1);
    let params: Vec<f32> = rng::normal_vec(&mut rng, n).iter().map(|x| x * 0.1).collect();
    let uniforms = rng::uniform_vec(&mut rng, n);
    let x = rng::normal_vec(&mut rng, batch * dim);
    let y: Vec<i32> = (0..batch).map(|_| (rng::uniform_f32(&mut rng) * 10.0) as i32).collect();
    let xs = [batch, dim];
    let ys = [batch];
    let inputs = [Input::F32(&x, &xs), Input::I32(&y, &ys)];

    let (loss_raw, grad_raw) = rt.grad("mlp_grad", &params, &inputs).unwrap();
    let (loss_q, qgrad, scales) = rt.grad_q("mlp_grad_q", &params, &uniforms, &inputs).unwrap();

    // same forward pass ⇒ identical loss
    assert!((loss_raw - loss_q).abs() < 1e-6, "{loss_raw} vs {loss_q}");
    assert_eq!(qgrad.len(), n);
    assert_eq!(scales.len(), q.buckets);

    // every qgrad value lies on the level grid of its bucket, within one
    // level of the raw gradient (max-norm fused artifact)
    for (bi, chunk) in qgrad.chunks(q.bucket).enumerate() {
        let scale = scales[bi];
        let raw = &grad_raw[bi * q.bucket..(bi * q.bucket + chunk.len()).min(n)];
        if scale == 0.0 {
            assert!(chunk.iter().all(|&v| v == 0.0));
            continue;
        }
        for (j, (&qv, &rv)) in chunk.iter().zip(raw).enumerate() {
            let lev = qv.abs() * q.s as f32 / scale;
            assert!(
                (lev - lev.round()).abs() < 1e-3,
                "bucket {bi} coord {j}: off-grid level {lev}"
            );
            assert!(
                (qv - rv).abs() <= scale / q.s as f32 + 1e-6,
                "bucket {bi} coord {j}: more than one level from raw"
            );
        }
    }
}

#[test]
fn mlp_training_reduces_heldout_loss_under_all_compressors() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("mlp_grad").unwrap().clone();
    let dim = art.inputs[1].shape[1];
    let batch = art.batch.unwrap();

    let mut finals = Vec::new();
    for spec in [CompressorSpec::Fp32, CompressorSpec::qsgd_4bit(), CompressorSpec::OneBit { column: 512 }] {
        let mut src = RuntimeSource::new(
            &rt,
            "mlp_grad",
            Workload::Classify { data: ClassifyData::mnist_like(dim, 10, 3), batch },
        )
        .unwrap();
        let first = src.eval(&vec![0.01; art.params.unwrap()]).unwrap();
        let mut cfg = SyncConfig::quick(4, 40, spec, 0.15);
        cfg.eval_every = 10;
        cfg.plan = art.layout.as_ref().map(QuantPlan::quantize_all);
        let res = SyncTrainer::new(cfg).run(&mut src).unwrap();
        let last = res.eval.last().unwrap();
        assert!(last < first * 0.5, "{}: eval {first} -> {last}", res.label);
        finals.push((res.label, last));
    }
    // parity: QSGD 4-bit within 20% of fp32's held-out loss
    let fp = finals[0].1;
    assert!(finals[1].1 < fp * 1.2 + 0.05, "{:?}", finals);
}

#[test]
fn transformer_loss_starts_near_uniform_and_drops() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("tfm_grad").unwrap().clone();
    let batch = art.batch.unwrap();
    let seq_plus_1 = art.inputs[1].shape[1];
    let mut src = RuntimeSource::new(
        &rt,
        "tfm_grad",
        Workload::Lm { corpus: TokenCorpus::new(512, 0), batch, seq_plus_1 },
    )
    .unwrap();

    let mut cfg = SyncConfig::quick(2, 30, CompressorSpec::qsgd_4bit(), 0.25);
    cfg.init_scale = 0.05;
    cfg.log_every = 1;
    let res = SyncTrainer::new(cfg).run(&mut src).unwrap();
    let first = res.loss.points[0].1;
    let last = res.loss.tail_mean(3);
    // untrained ≈ ln(512) ≈ 6.24
    assert!((first - 512f64.ln()).abs() < 1.0, "initial loss {first}");
    assert!(last < first - 0.3, "no learning: {first} -> {last}");
}

#[test]
fn wrong_input_arity_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = match rt.execute("mlp_grad", &[]) {
        Err(e) => e,
        Ok(_) => panic!("empty input list accepted"),
    };
    assert!(err.to_string().contains("expects"), "{err}");
    assert!(rt.execute("nonexistent", &[]).is_err());
}
