//! Layer-1 ⇄ Layer-3 cross-validation: the Rust quantizer and the Pallas
//! kernel (executed through the AOT `quantize` artifact) must assign the
//! same levels given the same uniforms — the three implementations (Rust,
//! Pallas, jnp oracle) share one level-assignment contract.
//!
//! The Pallas artifact is a 64×512 L2-norm s=15 kernel (see aot.py).
//! f32 norm computation can differ by an ulp between XLA's reduction order
//! and Rust's sequential sum, which may flip a randomized-rounding decision
//! on coordinates whose `r` sits within that ulp of a boundary — so we
//! require exact agreement on ≥99.9% of coordinates and |Δlevel| ≤ 1 on the
//! rest, plus bitwise-level agreement of the dequantized values within
//! tolerance.

use qsgd::quant::{stochastic, Norm};
use qsgd::runtime::{artifact, Input, Runtime};
use qsgd::util::rng::{self, Xoshiro256};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifact::default_dir().join("manifest.json").exists() {
        eprintln!("[skipped: run `make artifacts` first]");
        return None;
    }
    Some(Runtime::from_default_dir().expect("runtime init"))
}

#[test]
fn rust_quantizer_matches_pallas_kernel() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("quantize").unwrap().clone();
    let q = art.quant.unwrap();
    let (nb, d) = (art.inputs[0].shape[0], art.inputs[0].shape[1]);
    assert_eq!((nb, d), (q.buckets, q.bucket));

    let mut rng = Xoshiro256::from_u64(7);
    let v = rng::normal_vec(&mut rng, nb * d);
    let u = rng::uniform_vec(&mut rng, nb * d);
    let shape = [nb, d];
    let out = rt
        .execute("quantize", &[Input::F32(&v, &shape), Input::F32(&u, &shape)])
        .unwrap();
    let q_pallas = out[0].to_vec::<f32>().unwrap();
    let scales = out[1].to_vec::<f32>().unwrap();

    let q_rust = stochastic::quantize_with_uniforms(&v, &u, q.s, d, Norm::L2);

    let mut mismatches = 0usize;
    for (bi, bucket) in q_rust.buckets.iter().enumerate() {
        // scales agree to f32 reduction tolerance
        let rel = (bucket.scale - scales[bi]).abs() / bucket.scale.max(1e-12);
        assert!(rel < 1e-5, "bucket {bi}: scale {} vs pallas {}", bucket.scale, scales[bi]);
        let k = scales[bi] / q.s as f32;
        for (j, &lev) in bucket.levels.iter().enumerate() {
            let pallas_val = q_pallas[bi * d + j];
            let pallas_lev = (pallas_val / k).round() as i32;
            if pallas_lev != lev {
                mismatches += 1;
                assert!(
                    (pallas_lev - lev).abs() <= 1,
                    "bucket {bi} coord {j}: rust {lev} vs pallas {pallas_lev}"
                );
            }
        }
    }
    let total = nb * d;
    assert!(
        (mismatches as f64) < total as f64 * 1e-3,
        "{mismatches}/{total} level disagreements (boundary-ulp budget is 0.1%)"
    );
    println!("levels agree on {}/{} coordinates", total - mismatches, total);
}

#[test]
fn pallas_kernel_is_unbiased_through_the_runtime() {
    // Monte-Carlo over uniforms drawn in Rust, executed on the artifact:
    // E[Q_s(v)] = v (Lemma 3.1(i)) must hold through the full AOT path.
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().get("quantize").unwrap().clone();
    let q = art.quant.unwrap();
    let (nb, d) = (art.inputs[0].shape[0], art.inputs[0].shape[1]);
    let shape = [nb, d];

    let mut rng = Xoshiro256::from_u64(8);
    let v = rng::normal_vec(&mut rng, nb * d);
    let trials = 60;
    let mut acc = vec![0.0f64; nb * d];
    for _ in 0..trials {
        let u = rng::uniform_vec(&mut rng, nb * d);
        let out = rt
            .execute("quantize", &[Input::F32(&v, &shape), Input::F32(&u, &shape)])
            .unwrap();
        for (a, x) in acc.iter_mut().zip(out[0].to_vec::<f32>().unwrap()) {
            *a += x as f64 / trials as f64;
        }
    }
    // per-coordinate stderr ≈ scale/(s·√trials); scale ≈ ‖bucket‖₂ ≈ √d
    let tol = 6.0 * (d as f64).sqrt() / (q.s as f64 * (trials as f64).sqrt());
    let max_dev = acc
        .iter()
        .zip(&v)
        .map(|(a, &x)| (a - x as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(max_dev < tol, "bias {max_dev} exceeds {tol}");
}
