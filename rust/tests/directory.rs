//! The v3 bucket-offset directory frame: format pinning, threshold
//! behavior, and serial-vs-parallel decode identity.
//!
//! * frames below the directory threshold stay **byte-identical** to the
//!   v1/v2 formats (golden frames in `nuqsgd.rs` pin the exact bytes; here
//!   we pin the version nibble and the fused/two-phase agreement around the
//!   threshold);
//! * directory-bearing frames are pinned by goldens whose bytes are
//!   assembled independently of the encoder (BitWriter + Elias primitives);
//! * serial decode, parallel decode at every thread budget, and the
//!   directory-less frame of the same quantized gradient all produce
//!   bit-identical results;
//! * the fused pipeline and the two-phase oracle agree byte-for-byte above
//!   the threshold, where both emit the directory.

mod common;

use qsgd::coding::bitstream::BitWriter;
use qsgd::coding::gradient::{
    self, Regime, FRAME_MAGIC, FRAME_VERSION, FRAME_VERSION_DIR, FRAME_VERSION_GRID,
};
use qsgd::coding::{elias, QsgdCodec, TwoPhaseQsgd};
use qsgd::prop_assert;
use qsgd::quant::{
    stochastic, Codec, EncodeSession, LevelGrid, Norm, QuantBucket, QuantizedGradient,
};
use qsgd::util::check::forall;
use qsgd::util::rng::{self, Xoshiro256};

fn frame(
    grid: LevelGrid,
    bucket_size: usize,
    norm: Norm,
    n: usize,
    buckets: Vec<QuantBucket>,
) -> QuantizedGradient {
    QuantizedGradient { s: grid.s(), grid, bucket_size, norm, n, buckets }
}

fn hex(s: &str) -> Vec<u8> {
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
        .collect()
}

/// Assemble the expected v3 bytes for a *dense* single-level-stream frame,
/// independently of the encoder: header fields, grid tag, Elias'(byte len)
/// directory, byte alignment, then the given pre-encoded bucket payloads.
fn assemble_v3_dense(
    grid_tag: u64,
    s: u64,
    n: u64,
    bucket: u64,
    payloads: &[Vec<u8>],
) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(FRAME_VERSION_DIR, 4);
    w.write_bit(false); // dense
    w.write_bit(true); // max norm
    elias::encode(&mut w, s);
    elias::encode0(&mut w, n);
    elias::encode(&mut w, bucket);
    elias::encode(&mut w, grid_tag);
    for p in payloads {
        elias::encode0(&mut w, p.len() as u64);
    }
    w.align_to_byte();
    for p in payloads {
        w.extend_aligned(p);
    }
    w.into_bytes()
}

/// Encode one dense bucket body (scale + per-coordinate Elias'(|ℓ|) + sign
/// bit for nonzeros) to padded bytes, with the bit-level primitives only.
fn dense_bucket_payload(scale: f32, levels: &[i32]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_f32(scale);
    for &l in levels {
        elias::encode0(&mut w, l.unsigned_abs() as u64);
        if l != 0 {
            w.write_bit(l < 0);
        }
    }
    w.into_bytes()
}

#[test]
fn golden_v3_uniform_directory_frame() {
    // The v1 golden frame's quantized gradient, with the directory forced:
    // s=1, n=2, bucket=2, max-norm, dense, levels [0, -1], scale 1.0.
    let q = frame(
        LevelGrid::uniform(1),
        2,
        Norm::Max,
        2,
        vec![QuantBucket { scale: 1.0, levels: vec![0, -1] }],
    );
    let bytes = gradient::encode_with_directory(&q, Regime::Dense, true);
    // magic | v3 | dense | max | Elias(1) | Elias'(2) | Elias(2) |
    // tag Elias(3) | dir Elias'(5) | pad | payload (5 bytes)
    assert_eq!(bytes, hex("a535a6b03f80000048"));
    // and the independently assembled bytes agree
    let payload = dense_bucket_payload(1.0, &[0, -1]);
    assert_eq!(payload, hex("3f80000048"));
    assert_eq!(bytes, assemble_v3_dense(3, 1, 2, 2, &[payload]));
    // round-trip through both decoders
    assert_eq!(gradient::decode(&bytes).unwrap(), q);
    let mut acc = vec![0.0f32; 2];
    assert_eq!(gradient::par_decode_add(&bytes, 1.0, &mut acc).unwrap(), 2);
    assert_eq!(acc, q.dequantize());
    // the directory-less encoding of the same gradient is the v1 golden
    assert_eq!(gradient::encode_with_directory(&q, Regime::Dense, false), hex("a515a1fc00000240"));
}

#[test]
fn golden_v3_multi_bucket_exponential_frame() {
    // Exponential grid s=2 ({0, 1/2, 1}), n=3, bucket=2 ⇒ two buckets
    // ([1, -2] scale 2.0 and [1] scale 0.5): exercises multiple directory
    // entries and the ragged tail bucket.
    let q = frame(
        LevelGrid::exponential(2),
        2,
        Norm::Max,
        3,
        vec![
            QuantBucket { scale: 2.0, levels: vec![1, -2] },
            QuantBucket { scale: 0.5, levels: vec![1] },
        ],
    );
    let bytes = gradient::encode_with_directory(&q, Regime::Dense, true);
    let payloads = vec![dense_bucket_payload(2.0, &[1, -2]), dense_bucket_payload(0.5, &[1])];
    assert_eq!(bytes, assemble_v3_dense(1, 2, 3, 2, &payloads));
    assert_eq!(gradient::decode(&bytes).unwrap(), q);
    assert_eq!(gradient::decode(&bytes).unwrap().dequantize(), vec![1.0, -2.0, 0.25]);
}

#[test]
fn version_nibble_tracks_the_threshold_rule() {
    let mut r = Xoshiro256::from_u64(1);
    let below = rng::normal_vec(&mut r, gradient::DIRECTORY_MIN_COORDS - 1);
    let above = rng::normal_vec(&mut r, gradient::DIRECTORY_MIN_COORDS);
    for (grid, want_plain) in [
        (LevelGrid::uniform(7), FRAME_VERSION),
        (LevelGrid::exponential(7), FRAME_VERSION_GRID),
    ] {
        let c = QsgdCodec::with_grid(grid.clone(), 512, Norm::Max, None);
        let small = c.session(Xoshiro256::from_u64(2)).compress(&below);
        assert_eq!((small[1] >> 4) as u64, want_plain, "{}", grid.label());
        let big = c.session(Xoshiro256::from_u64(3)).compress(&above);
        assert_eq!((big[1] >> 4) as u64, FRAME_VERSION_DIR, "{}", grid.label());
        // single-bucket frames never carry a directory, however large
        let whole = QsgdCodec::with_grid(grid.clone(), usize::MAX, Norm::Max, None);
        let one = whole.session(Xoshiro256::from_u64(4)).compress(&above);
        assert_eq!((one[1] >> 4) as u64, want_plain, "{}", grid.label());
    }
}

#[test]
fn fused_matches_two_phase_above_the_threshold() {
    // Both encoders must flip to the directory at exactly the same size and
    // produce identical bytes on both sides of it.
    let mut r = Xoshiro256::from_u64(5);
    for n in [
        gradient::DIRECTORY_MIN_COORDS - 1,
        gradient::DIRECTORY_MIN_COORDS,
        gradient::DIRECTORY_MIN_COORDS + 513,
    ] {
        let v = rng::normal_vec(&mut r, n);
        let a = QsgdCodec::new(7, 512, Norm::Max, None)
            .session(Xoshiro256::from_u64(n as u64))
            .compress(&v);
        let b = TwoPhaseQsgd::new(7, 512, Norm::Max, None)
            .session(Xoshiro256::from_u64(n as u64))
            .compress(&v);
        assert_eq!(a, b, "n={n}");
        let a = QsgdCodec::nuqsgd_with_bits(4, 512)
            .session(Xoshiro256::from_u64(n as u64 ^ 0xF))
            .compress(&v);
        let b = TwoPhaseQsgd::nuqsgd_with_bits(4, 512)
            .session(Xoshiro256::from_u64(n as u64 ^ 0xF))
            .compress(&v);
        assert_eq!(a, b, "nuqsgd n={n}");
    }
}

#[test]
fn prop_directory_roundtrip_serial_equals_parallel() {
    forall("directory-roundtrip", 80, 2500, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let bucket = [1usize, 3, 64, 512][g.usize_in(0, 3)];
        let norm = common::gen_norm(g);
        let regime = if g.bool() { Regime::Sparse } else { Regime::Dense };
        let q = stochastic::quantize_grid(&v, &grid, bucket, norm, g.rng);
        let plain = gradient::encode_with_directory(&q, regime, false);
        let dirred = gradient::encode_with_directory(&q, regime, true);
        let qd = gradient::decode(&dirred).map_err(|e| e.to_string())?;
        prop_assert!(qd == q, "directory frame decode mismatch (n={n})");
        let mut base = vec![0.5f32; n];
        gradient::decode_add(&plain, 0.25, &mut base).map_err(|e| e.to_string())?;
        for threads in [1usize, 2, 5, 16] {
            let mut acc = vec![0.5f32; n];
            gradient::par_decode_add_threads(&dirred, 0.25, &mut acc, threads)
                .map_err(|e| e.to_string())?;
            let same = acc
                .iter()
                .zip(&base)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            prop_assert!(same, "parallel decode diverged (n={n}, threads={threads})");
        }
        Ok(())
    });
}

#[test]
fn plan_codec_threads_path_is_bit_identical() {
    // Through the coordinator's segment framing: a plan whose quantized
    // segment is large enough to carry the directory must decode the same
    // under any intra-message budget.
    use qsgd::coordinator::exchange::PlanCodec;
    use qsgd::coordinator::CompressorSpec;
    use qsgd::models::layout::{ParamLayout, QuantPlan};

    let l = ParamLayout::synthetic(&[("small", vec![64]), ("big", vec![400, 200])]);
    let plan = QuantPlan::build(&l, 10_000);
    let mut rng = Xoshiro256::from_u64(8);
    let grad = rng::normal_vec(&mut rng, l.total_params());
    let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
    let msg = pc.session(Xoshiro256::from_u64(9)).compress(&grad);
    let mut base = vec![0.0f32; grad.len()];
    pc.decode_add(&msg, 1.0, &mut base).unwrap();
    for threads in [2usize, 4, 32] {
        let mut acc = vec![0.0f32; grad.len()];
        pc.decode_add_threads(&msg, 1.0, &mut acc, threads).unwrap();
        assert_eq!(acc, base, "threads={threads}");
    }
}
