//! Decoder robustness: truncated, bit-flipped and length-lying frames must
//! come back as `Err` — never a panic, and allocations always bounded by a
//! dimension cap: the caller's expected length on the
//! `decode_expecting`/`decode_add` paths the coordinators use, and
//! `MAX_FRAME_DIM` on raw `decode`. (The sparse regime can legitimately
//! encode a huge all-zero bucket in ~33 bits, so raw `decode` of an
//! in-cap sparse header is *by design* allowed to allocate up to the cap —
//! no stream-length bound exists for it, unlike the dense regime's
//! one-bit-per-coordinate check.)
//!
//! `decode` is deterministic and reads a strict prefix of the stream, so any
//! truncation below the encoded length must hit exhaustion; bit flips may
//! legitimately decode (e.g. a flipped scale bit is still a valid frame),
//! so for those the contract is "Err or a self-consistent Ok".

use qsgd::coding::bitstream::BitWriter;
use qsgd::coding::gradient::{
    self, Regime, FRAME_MAGIC, FRAME_VERSION, FRAME_VERSION_DIR, FRAME_VERSION_GRID,
};
use qsgd::coding::{elias, QsgdCodec};
use qsgd::config::CodecOptions;
use qsgd::quant::{Codec, EncodeSession, LevelGrid, Norm};
use qsgd::util::check::forall;
use qsgd::util::rng::{self, Xoshiro256};

fn sample_frames() -> Vec<(Vec<u8>, usize)> {
    let mut data_rng = Xoshiro256::from_u64(5);
    let v: Vec<f32> = (0..700).map(|_| rng::normal_f32(&mut data_rng)).collect();
    let mut frames = Vec::new();
    for (grid, norm, regime) in [
        (LevelGrid::uniform(7), Norm::Max, Some(Regime::Dense)),
        (LevelGrid::uniform(1), Norm::L2, Some(Regime::Sparse)),
        (LevelGrid::exponential(7), Norm::Max, Some(Regime::Dense)),
        (LevelGrid::custom(vec![0.1, 0.5, 1.0]).unwrap(), Norm::Max, Some(Regime::Sparse)),
    ] {
        let c = QsgdCodec::with_grid(grid, 64, norm, regime);
        frames.push((c.session(Xoshiro256::from_u64(9)).compress(&v), v.len()));
    }
    // v3 (bucket-offset directory) frames, forced below the size threshold
    // (via CodecOptions) so the whole truncation/bit-flip sweep stays cheap
    for (grid, regime) in [
        (LevelGrid::uniform(7), Some(Regime::Dense)),
        (LevelGrid::exponential(7), Some(Regime::Sparse)),
    ] {
        let c = QsgdCodec::with_grid(grid, 64, Norm::Max, regime)
            .with_options(CodecOptions { directory: Some(true), ..CodecOptions::default() });
        frames.push((c.session(Xoshiro256::from_u64(9)).compress(&v), v.len()));
    }
    frames
}

#[test]
fn every_truncation_is_rejected() {
    for (bytes, n) in sample_frames() {
        assert!(gradient::decode(&bytes).is_ok(), "baseline frame must decode");
        for k in 0..bytes.len() {
            let cut = &bytes[..k];
            assert!(gradient::decode(cut).is_err(), "truncation at {k}/{} decoded", bytes.len());
            assert!(gradient::decode_expecting(cut, n).is_err());
            let mut acc = vec![0.0f32; n];
            assert!(gradient::decode_add(cut, 1.0, &mut acc).is_err());
        }
    }
}

#[test]
fn bit_flips_never_panic_and_any_ok_is_self_consistent() {
    for (bytes, n) in sample_frames() {
        for bit in 0..bytes.len() * 8 {
            let mut m = bytes.clone();
            m[bit / 8] ^= 1 << (7 - bit % 8);
            // must not panic or OOM; Ok frames must uphold their own header
            if let Ok(q) = gradient::decode(&m) {
                let total: usize = q.buckets.iter().map(|b| b.levels.len()).sum();
                assert_eq!(total, q.n, "bit {bit}: inconsistent decoded shape");
                assert!(
                    q.buckets.iter().all(|b| b.levels.iter().all(|&l| l.unsigned_abs() <= q.s)),
                    "bit {bit}: level beyond s"
                );
            }
            let mut acc = vec![0.0f32; n];
            let _ = gradient::decode_add(&m, 0.5, &mut acc);
            let _ = gradient::decode_expecting(&m, n);
        }
        // flips inside the first byte corrupt the magic: always Err. (The
        // version nibble is no longer always-Err: with v1/v2/v3 all valid,
        // a single flipped bit can map one version onto another, and the
        // reinterpreted stream falls under the generic "Err or
        // self-consistent Ok" contract checked above.)
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[bit / 8] ^= 1 << (7 - bit % 8);
            assert!(gradient::decode(&m).is_err(), "magic bit {bit} accepted");
        }
    }
}

/// Hand-assemble a frame header lying about its dimensions.
fn lying_header(s: u64, n: u64, bucket: u64, version: u64, sparse: bool) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(version, 4);
    w.write_bit(sparse);
    w.write_bit(true); // max norm
    elias::encode(&mut w, s);
    elias::encode0(&mut w, n);
    elias::encode(&mut w, bucket);
    w.into_bytes()
}

#[test]
fn hostile_header_dimensions_are_rejected_without_oom() {
    // n far beyond any plausible model: rejected by the frame cap, cheaply.
    let huge = lying_header(7, 1 << 50, 1 << 50, FRAME_VERSION, true);
    assert!(gradient::decode(&huge).is_err());
    let mut acc = vec![0.0f32; 16];
    assert!(gradient::decode_add(&huge, 1.0, &mut acc).is_err());
    assert!(gradient::decode_expecting(&huge, 16).is_err());

    // n within the cap but far beyond the message: decode_expecting bounds
    // it by the caller's length before any size-proportional allocation...
    let lying = lying_header(7, 1 << 27, 1 << 27, FRAME_VERSION, true);
    assert!(gradient::decode_expecting(&lying, 1024).is_err());
    assert!(gradient::decode_add(&lying, 1.0, &mut acc).is_err());
    // ...and the dense regime is caught by the bits-remaining check.
    let lying_dense = lying_header(7, 1 << 27, 512, FRAME_VERSION, false);
    assert!(gradient::decode(&lying_dense).is_err());

    // s = 0 and absurd s
    assert!(gradient::decode(&lying_header(0, 8, 8, FRAME_VERSION, false)).is_err());
    assert!(gradient::decode(&lying_header(1 << 40, 8, 8, FRAME_VERSION, false)).is_err());
    // zero bucket size
    assert!(gradient::decode(&lying_header(7, 8, 0, FRAME_VERSION, false)).is_err());
    // unsupported version
    assert!(gradient::decode(&lying_header(7, 8, 8, 15, false)).is_err());
    // v3 without the mandatory grid tag + directory: exhausts the stream
    assert!(gradient::decode(&lying_header(7, 8, 8, 3, false)).is_err());
}

#[test]
fn hostile_grid_tags_are_rejected() {
    let with_tag = |tag: u64, s: u64, points: &[f32]| -> Vec<u8> {
        let mut w = BitWriter::new();
        w.write_bits(FRAME_MAGIC, 8);
        w.write_bits(FRAME_VERSION_GRID, 4);
        w.write_bit(false);
        w.write_bit(true);
        elias::encode(&mut w, s);
        elias::encode0(&mut w, 4);
        elias::encode(&mut w, 4);
        elias::encode(&mut w, tag);
        for &p in points {
            w.write_f32(p);
        }
        w.into_bytes()
    };
    // unknown tag
    assert!(gradient::decode(&with_tag(9, 2, &[])).is_err());
    // exponential grid deeper than f32 can represent
    assert!(gradient::decode(&with_tag(1, 200, &[])).is_err());
    // custom grid: non-monotone, non-positive, NaN, not ending at 1, and a
    // point count the stream cannot back
    assert!(gradient::decode(&with_tag(2, 2, &[0.5, 0.25])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[-0.5, 1.0])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[f32::NAN, 1.0])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[0.25, 0.5])).is_err());
    assert!(gradient::decode(&with_tag(2, 4096, &[0.25, 1.0])).is_err());
    // a truncated-but-valid-shape grid still decodes the grid, then fails on
    // the missing bucket data
    assert!(gradient::decode(&with_tag(2, 2, &[0.25, 1.0])).is_err());
}

/// Hand-assemble a v3 frame: header, uniform grid tag, the given directory
/// byte lengths (Elias'), alignment, then raw payload bytes.
fn v3_frame(s: u64, n: u64, bucket: u64, dir_lens: &[u64], payload: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(FRAME_VERSION_DIR, 4);
    w.write_bit(false); // dense
    w.write_bit(true); // max norm
    elias::encode(&mut w, s);
    elias::encode0(&mut w, n);
    elias::encode(&mut w, bucket);
    elias::encode(&mut w, 3); // GRID_TAG_UNIFORM
    for &l in dir_lens {
        elias::encode(&mut w, l + 1);
    }
    w.align_to_byte();
    w.extend_aligned(payload);
    w.into_bytes()
}

#[test]
fn corrupt_directories_are_rejected_without_panic_or_oom() {
    let assert_all_reject = |bytes: &[u8], what: &str| {
        assert!(gradient::decode(bytes).is_err(), "{what}: decode accepted");
        let mut acc = vec![0.0f32; 128];
        assert!(gradient::decode_add(bytes, 1.0, &mut acc).is_err(), "{what}: decode_add");
        assert!(
            gradient::par_decode_add_threads(bytes, 1.0, &mut acc, 4).is_err(),
            "{what}: par_decode_add"
        );
        assert!(gradient::decode_expecting(bytes, 128).is_err(), "{what}: decode_expecting");
    };

    // a valid 128-coord / 64-bucket dense payload to splice under lying dirs
    let c = QsgdCodec::new(7, 64, Norm::Max, Some(Regime::Dense))
        .with_options(CodecOptions { directory: Some(true), ..CodecOptions::default() });
    let v: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) / 64.0).collect();
    let good = c.session(Xoshiro256::from_u64(1)).compress(&v);
    assert!(gradient::decode(&good).is_ok());

    // directory lengths that overrun the message
    assert_all_reject(&v3_frame(7, 128, 64, &[1 << 40, 1 << 40], &[0; 8]), "overrun");
    // u64-overflowing cumulative length
    assert_all_reject(&v3_frame(7, 128, 64, &[u64::MAX - 2, 8], &[0; 8]), "overflow");
    // zero-length buckets: below the 5-byte scale+levels floor
    assert_all_reject(&v3_frame(7, 128, 64, &[0, 0], &[]), "zero-length");
    // lengths lying short: also below the per-bucket payload floor
    assert_all_reject(&v3_frame(7, 128, 64, &[2, 2], &[0x3f, 0x80, 0x00, 0x00]), "short");
    // allocation amplification: n = 2^20 at bucket 1 claims 2^20 directory
    // entries, and an all-zero directory body decodes every entry as len 0
    // (one bit each) — the per-entry payload floor must reject this at the
    // FIRST entry, long before a 2^20-entry directory Vec is built
    let mut amp = v3_frame(7, 1 << 20, 1, &[], &[]);
    amp.extend_from_slice(&vec![0u8; 1 << 18]); // ~2 Mbit of zero "entries"
    assert!(gradient::decode(&amp).is_err(), "amplification vector accepted");
    // truncated inside the directory varints
    let full = v3_frame(7, 128, 64, &[40, 40], &[0u8; 80]);
    assert_all_reject(&full[..3], "truncated dir");
    // directory entry count mismatch is not representable (count is derived
    // from n and bucket), but a bucket count lying huge must be bounded by
    // the stream before any allocation: n = 2^27 coords at bucket 1 claims
    // 2^27 directory entries against a ~16-byte message.
    assert_all_reject(&v3_frame(7, 1 << 27, 1, &[], &[]), "huge bucket count");

    // uniform grid tag is only valid in v3 — a v2 frame carrying it fails
    let mut w = BitWriter::new();
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(FRAME_VERSION_GRID, 4);
    w.write_bit(false);
    w.write_bit(true);
    elias::encode(&mut w, 7);
    elias::encode0(&mut w, 4);
    elias::encode(&mut w, 4);
    elias::encode(&mut w, 3); // GRID_TAG_UNIFORM — v3-only
    assert!(gradient::decode(&w.into_bytes()).is_err());

    // flipping any single bit of a valid directory frame never panics and
    // keeps Ok decodes self-consistent (exhaustive sweep runs in
    // bit_flips_never_panic_and_any_ok_is_self_consistent; here we also
    // drive the *parallel* decoder over the corrupted frames)
    for bit in 0..good.len() * 8 {
        let mut m = good.clone();
        m[bit / 8] ^= 1 << (7 - bit % 8);
        let mut acc = vec![0.0f32; 128];
        let _ = gradient::par_decode_add_threads(&m, 1.0, &mut acc, 4);
    }
}

#[test]
fn prop_random_bytes_never_panic() {
    forall("fuzz-decode", 300, 600, |g| {
        let len = g.usize_in(0, g.size);
        let mut bytes = vec![0u8; len];
        for b in bytes.iter_mut() {
            *b = (g.u32() & 0xff) as u8;
        }
        // fully random streams: almost always Err; required: no panic/OOM
        let _ = gradient::decode(&bytes);
        let mut acc = vec![0.0f32; 64];
        let _ = gradient::decode_add(&bytes, 1.0, &mut acc);
        let _ = gradient::decode_expecting(&bytes, 64);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Streaming flavor: the same hostile vectors through the socket transport's
// framed reader, delivered in adversarial chunk sizes. The framing layer
// must reassemble partial reads faithfully, reject truncation and lying
// length prefixes cleanly, and never let a prefix claim drive allocation
// beyond what the peer actually delivers.
// ---------------------------------------------------------------------------

use qsgd::transport::{write_frame, FrameReader};

/// A `Read` source that doles out an in-memory buffer in hostile chunk
/// sizes: fixed k-byte slivers or seeded random splits — the shapes a
/// loopback TCP stream legitimately produces under small MTUs and
/// scheduling noise.
struct ChunkReader<'a> {
    data: &'a [u8],
    pos: usize,
    plan: ChunkPlan,
}

enum ChunkPlan {
    Fixed(usize),
    Random(Xoshiro256),
}

impl<'a> ChunkReader<'a> {
    fn fixed(data: &'a [u8], k: usize) -> Self {
        ChunkReader { data, pos: 0, plan: ChunkPlan::Fixed(k.max(1)) }
    }

    fn random(data: &'a [u8], seed: u64) -> Self {
        ChunkReader { data, pos: 0, plan: ChunkPlan::Random(Xoshiro256::from_u64(seed)) }
    }
}

impl std::io::Read for ChunkReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let left = self.data.len() - self.pos;
        if left == 0 || buf.is_empty() {
            return Ok(0);
        }
        let want = match &mut self.plan {
            ChunkPlan::Fixed(k) => *k,
            ChunkPlan::Random(rng) => 1 + rng::uniform_usize(rng, 7),
        };
        let n = want.min(left).min(buf.len());
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[test]
fn streamed_frames_survive_one_byte_and_random_chunking() {
    let frames = sample_frames();
    let mut wire = Vec::new();
    for (bytes, _) in &frames {
        write_frame(&mut wire, bytes).unwrap();
    }
    for plan in 0..3 {
        let mut src = match plan {
            0 => ChunkReader::fixed(&wire, 1),
            1 => ChunkReader::fixed(&wire, 3),
            _ => ChunkReader::random(&wire, 42),
        };
        let mut reader = FrameReader::new();
        for (bytes, n) in &frames {
            let got = reader.read_frame(&mut src).unwrap().expect("frame present");
            assert_eq!(got, &bytes[..], "plan {plan}: reassembled payload differs");
            let q = gradient::decode(got).expect("reassembled frame must decode");
            assert_eq!(q.n, *n);
        }
        assert!(reader.read_frame(&mut src).unwrap().is_none(), "plan {plan}: clean EOF");
    }
}

#[test]
fn streamed_truncations_reject_cleanly() {
    let (bytes, _) = sample_frames().swap_remove(0);
    let mut framed = Vec::new();
    write_frame(&mut framed, &bytes).unwrap();
    // every proper prefix of the framed message, delivered byte by byte:
    // zero bytes is a clean end-of-stream (Ok(None)); anything between is a
    // mid-prefix or mid-frame truncation and must be an error, not a hang
    // or a short Ok
    for cut in 0..framed.len() {
        let mut reader = FrameReader::new();
        let res = reader.read_frame(&mut ChunkReader::fixed(&framed[..cut], 1));
        if cut == 0 {
            assert!(matches!(res, Ok(None)), "cut 0 must be clean EOF");
        } else {
            assert!(res.is_err(), "truncation at {cut}/{} accepted", framed.len());
        }
    }
}

#[test]
fn streamed_corrupt_payloads_are_delivered_verbatim_then_rejected_by_decode() {
    for (bytes, n) in sample_frames() {
        // an honest frame around a truncated codec payload: the transport
        // delivers it intact; the *decoder* is what rejects it
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            let mut wire = Vec::new();
            write_frame(&mut wire, &bytes[..cut]).unwrap();
            let mut reader = FrameReader::new();
            let got = reader
                .read_frame(&mut ChunkReader::random(&wire, cut as u64 + 1))
                .unwrap()
                .expect("framing is honest");
            assert_eq!(got, &bytes[..cut]);
            assert!(gradient::decode(got).is_err(), "truncated payload decoded");
            let mut acc = vec![0.0f32; n];
            assert!(gradient::decode_add(got, 1.0, &mut acc).is_err());
        }
        // single bit flip mid-payload: delivered verbatim; decode must not
        // panic (Err or self-consistent Ok, as in the direct sweep above)
        let mut m = bytes.clone();
        m[bytes.len() / 2] ^= 0x10;
        let mut wire = Vec::new();
        write_frame(&mut wire, &m).unwrap();
        let mut reader = FrameReader::new();
        let got =
            reader.read_frame(&mut ChunkReader::fixed(&wire, 1)).unwrap().expect("frame present");
        assert_eq!(got, &m[..]);
        let _ = gradient::decode(got);
        let mut acc = vec![0.0f32; n];
        let _ = gradient::decode_add(got, 1.0, &mut acc);
    }
}

#[test]
fn streamed_lying_length_prefix_cannot_balloon_memory() {
    // a prefix claiming 512 MiB (under the frame cap, so the cap check
    // passes) with only 100 bytes behind it: the reader must grow its
    // buffer proportionally to *delivery*, error out at EOF, and hold no
    // more than a couple of read-chunks of capacity
    let mut wire = Vec::new();
    wire.extend_from_slice(&(512u32 << 20).to_le_bytes());
    wire.extend_from_slice(&[0xAB; 100]);
    for plan in 0..2 {
        let mut src = match plan {
            0 => ChunkReader::fixed(&wire, 1),
            _ => ChunkReader::random(&wire, 7),
        };
        let mut reader = FrameReader::new();
        assert!(reader.read_frame(&mut src).is_err(), "plan {plan}: lying prefix accepted");
        assert!(
            reader.buf_capacity() <= 256 * 1024,
            "plan {plan}: allocated {} bytes against a 100-byte delivery",
            reader.buf_capacity()
        );
    }
}
