//! Decoder robustness: truncated, bit-flipped and length-lying frames must
//! come back as `Err` — never a panic, and allocations always bounded by a
//! dimension cap: the caller's expected length on the
//! `decode_expecting`/`decode_add` paths the coordinators use, and
//! `MAX_FRAME_DIM` on raw `decode`. (The sparse regime can legitimately
//! encode a huge all-zero bucket in ~33 bits, so raw `decode` of an
//! in-cap sparse header is *by design* allowed to allocate up to the cap —
//! no stream-length bound exists for it, unlike the dense regime's
//! one-bit-per-coordinate check.)
//!
//! `decode` is deterministic and reads a strict prefix of the stream, so any
//! truncation below the encoded length must hit exhaustion; bit flips may
//! legitimately decode (e.g. a flipped scale bit is still a valid frame),
//! so for those the contract is "Err or a self-consistent Ok".

use qsgd::coding::bitstream::BitWriter;
use qsgd::coding::gradient::{self, Regime, FRAME_MAGIC, FRAME_VERSION, FRAME_VERSION_GRID};
use qsgd::coding::{elias, FusedQsgd};
use qsgd::quant::{Compressor, LevelGrid, Norm};
use qsgd::util::check::forall;
use qsgd::util::rng::{self, Xoshiro256};

fn sample_frames() -> Vec<(Vec<u8>, usize)> {
    let mut data_rng = Xoshiro256::from_u64(5);
    let v: Vec<f32> = (0..700).map(|_| rng::normal_f32(&mut data_rng)).collect();
    let mut frames = Vec::new();
    for (grid, norm, regime) in [
        (LevelGrid::uniform(7), Norm::Max, Some(Regime::Dense)),
        (LevelGrid::uniform(1), Norm::L2, Some(Regime::Sparse)),
        (LevelGrid::exponential(7), Norm::Max, Some(Regime::Dense)),
        (LevelGrid::custom(vec![0.1, 0.5, 1.0]).unwrap(), Norm::Max, Some(Regime::Sparse)),
    ] {
        let mut c = FusedQsgd::with_grid(grid, 64, norm, regime);
        frames.push((c.compress(&v, &mut Xoshiro256::from_u64(9)), v.len()));
    }
    frames
}

#[test]
fn every_truncation_is_rejected() {
    for (bytes, n) in sample_frames() {
        assert!(gradient::decode(&bytes).is_ok(), "baseline frame must decode");
        for k in 0..bytes.len() {
            let cut = &bytes[..k];
            assert!(gradient::decode(cut).is_err(), "truncation at {k}/{} decoded", bytes.len());
            assert!(gradient::decode_expecting(cut, n).is_err());
            let mut acc = vec![0.0f32; n];
            assert!(gradient::decode_add(cut, 1.0, &mut acc).is_err());
        }
    }
}

#[test]
fn bit_flips_never_panic_and_any_ok_is_self_consistent() {
    for (bytes, n) in sample_frames() {
        for bit in 0..bytes.len() * 8 {
            let mut m = bytes.clone();
            m[bit / 8] ^= 1 << (7 - bit % 8);
            // must not panic or OOM; Ok frames must uphold their own header
            if let Ok(q) = gradient::decode(&m) {
                let total: usize = q.buckets.iter().map(|b| b.levels.len()).sum();
                assert_eq!(total, q.n, "bit {bit}: inconsistent decoded shape");
                assert!(
                    q.buckets.iter().all(|b| b.levels.iter().all(|&l| l.unsigned_abs() <= q.s)),
                    "bit {bit}: level beyond s"
                );
            }
            let mut acc = vec![0.0f32; n];
            let _ = gradient::decode_add(&m, 0.5, &mut acc);
            let _ = gradient::decode_expecting(&m, n);
        }
        // flips inside the first two bytes corrupt magic/version: always Err
        for bit in 0..12 {
            let mut m = bytes.clone();
            m[bit / 8] ^= 1 << (7 - bit % 8);
            assert!(gradient::decode(&m).is_err(), "header bit {bit} accepted");
        }
    }
}

/// Hand-assemble a frame header lying about its dimensions.
fn lying_header(s: u64, n: u64, bucket: u64, version: u64, sparse: bool) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(version, 4);
    w.write_bit(sparse);
    w.write_bit(true); // max norm
    elias::encode(&mut w, s);
    elias::encode0(&mut w, n);
    elias::encode(&mut w, bucket);
    w.into_bytes()
}

#[test]
fn hostile_header_dimensions_are_rejected_without_oom() {
    // n far beyond any plausible model: rejected by the frame cap, cheaply.
    let huge = lying_header(7, 1 << 50, 1 << 50, FRAME_VERSION, true);
    assert!(gradient::decode(&huge).is_err());
    let mut acc = vec![0.0f32; 16];
    assert!(gradient::decode_add(&huge, 1.0, &mut acc).is_err());
    assert!(gradient::decode_expecting(&huge, 16).is_err());

    // n within the cap but far beyond the message: decode_expecting bounds
    // it by the caller's length before any size-proportional allocation...
    let lying = lying_header(7, 1 << 27, 1 << 27, FRAME_VERSION, true);
    assert!(gradient::decode_expecting(&lying, 1024).is_err());
    assert!(gradient::decode_add(&lying, 1.0, &mut acc).is_err());
    // ...and the dense regime is caught by the bits-remaining check.
    let lying_dense = lying_header(7, 1 << 27, 512, FRAME_VERSION, false);
    assert!(gradient::decode(&lying_dense).is_err());

    // s = 0 and absurd s
    assert!(gradient::decode(&lying_header(0, 8, 8, FRAME_VERSION, false)).is_err());
    assert!(gradient::decode(&lying_header(1 << 40, 8, 8, FRAME_VERSION, false)).is_err());
    // zero bucket size
    assert!(gradient::decode(&lying_header(7, 8, 0, FRAME_VERSION, false)).is_err());
    // unsupported version
    assert!(gradient::decode(&lying_header(7, 8, 8, 3, false)).is_err());
}

#[test]
fn hostile_grid_tags_are_rejected() {
    let with_tag = |tag: u64, s: u64, points: &[f32]| -> Vec<u8> {
        let mut w = BitWriter::new();
        w.write_bits(FRAME_MAGIC, 8);
        w.write_bits(FRAME_VERSION_GRID, 4);
        w.write_bit(false);
        w.write_bit(true);
        elias::encode(&mut w, s);
        elias::encode0(&mut w, 4);
        elias::encode(&mut w, 4);
        elias::encode(&mut w, tag);
        for &p in points {
            w.write_f32(p);
        }
        w.into_bytes()
    };
    // unknown tag
    assert!(gradient::decode(&with_tag(9, 2, &[])).is_err());
    // exponential grid deeper than f32 can represent
    assert!(gradient::decode(&with_tag(1, 200, &[])).is_err());
    // custom grid: non-monotone, non-positive, NaN, not ending at 1, and a
    // point count the stream cannot back
    assert!(gradient::decode(&with_tag(2, 2, &[0.5, 0.25])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[-0.5, 1.0])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[f32::NAN, 1.0])).is_err());
    assert!(gradient::decode(&with_tag(2, 2, &[0.25, 0.5])).is_err());
    assert!(gradient::decode(&with_tag(2, 4096, &[0.25, 1.0])).is_err());
    // a truncated-but-valid-shape grid still decodes the grid, then fails on
    // the missing bucket data
    assert!(gradient::decode(&with_tag(2, 2, &[0.25, 1.0])).is_err());
}

#[test]
fn prop_random_bytes_never_panic() {
    forall("fuzz-decode", 300, 600, |g| {
        let len = g.usize_in(0, g.size);
        let mut bytes = vec![0u8; len];
        for b in bytes.iter_mut() {
            *b = (g.u32() & 0xff) as u8;
        }
        // fully random streams: almost always Err; required: no panic/OOM
        let _ = gradient::decode(&bytes);
        let mut acc = vec![0.0f32; 64];
        let _ = gradient::decode_add(&bytes, 1.0, &mut acc);
        let _ = gradient::decode_expecting(&bytes, 64);
        Ok(())
    });
}
