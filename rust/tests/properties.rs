//! Property-based tests over the coding and quantization substrates
//! (randomised inputs with seeded replay + size shrinking — see
//! `qsgd::util::check`; the offline build has no proptest). Case generators
//! live in `tests/common` and are shared with `fused_pipeline.rs` and
//! `nuqsgd.rs`.

mod common;

use qsgd::coding::bitstream::{BitReader, BitWriter};
use qsgd::coding::{elias, gradient};
use qsgd::coordinator::exchange::PlanCodec;
use qsgd::coordinator::CompressorSpec;
use qsgd::models::layout::{ParamLayout, QuantPlan};
use qsgd::prop_assert;
use qsgd::quant::{deterministic, stochastic, Codec, EncodeSession};
use qsgd::util::check::forall;
use qsgd::util::rng;
use qsgd::util::rng::Xoshiro256;

#[test]
fn prop_bitstream_roundtrip_random_ops() {
    forall("bitstream", 200, 2000, |g| {
        let n_ops = g.usize_in(1, g.size.max(1));
        let ops: Vec<(u64, u32)> = (0..n_ops)
            .map(|_| {
                let width = 1 + (g.u32() % 64);
                let v = (g.u32() as u64) << 32 | g.u32() as u64;
                let v = if width == 64 { v } else { v & ((1u64 << width) - 1) };
                (v, width)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, c) in &ops {
            w.write_bits(v, c);
        }
        let expect_bits: u64 = ops.iter().map(|&(_, c)| c as u64).sum();
        prop_assert!(w.len_bits() == expect_bits, "len_bits mismatch");
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, c) in &ops {
            let got = r.read_bits(c).map_err(|e| e.to_string())?;
            prop_assert!(got == v, "read {got} != wrote {v} (width {c})");
        }
        Ok(())
    });
}

#[test]
fn prop_elias_roundtrip_and_length() {
    forall("elias", 300, 64, |g| {
        let n = g.usize_in(1, 200);
        let ks: Vec<u64> = (0..n)
            .map(|_| {
                let bits = 1 + (g.u32() % 63);
                1 + ((g.u32() as u64) << 32 | g.u32() as u64) % (1u64 << bits)
            })
            .collect();
        let mut w = BitWriter::new();
        for &k in &ks {
            elias::encode(&mut w, k);
        }
        let total: u64 = ks.iter().map(|&k| elias::len(k)).sum();
        prop_assert!(w.len_bits() == total, "len() disagrees with encode()");
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &k in &ks {
            let got = elias::decode(&mut r).map_err(|e| e.to_string())?;
            prop_assert!(got == k, "decode {got} != {k}");
        }
        Ok(())
    });
}

#[test]
fn prop_gradient_codec_roundtrip() {
    // Over every grid family: what encode emits, decode reproduces exactly
    // (levels, scales, dims and the grid itself, via the v1/v2 headers).
    forall("gradient-codec", 120, 4000, |g| {
        let n = g.usize_in(0, g.size);
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let bucket = [16usize, 64, 512, 4096][g.usize_in(0, 3)];
        let norm = common::gen_norm(g);
        let u = rng::uniform_vec(g.rng, n);
        let q = stochastic::quantize_grid_with_uniforms(&v, &u, &grid, bucket, norm);
        for regime in [gradient::Regime::Sparse, gradient::Regime::Dense] {
            let bytes = gradient::encode(&q, regime);
            let back = gradient::decode(&bytes).map_err(|e| e.to_string())?;
            prop_assert!(
                back == q,
                "roundtrip mismatch {regime:?} n={n} d={bucket} grid={}",
                grid.label()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_invariants() {
    forall("quantizer", 150, 3000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let s = 1 + g.u32() % 200;
        let bucket = 1 + g.usize_in(0, n);
        let norm = common::gen_norm(g);
        let q = stochastic::quantize(&v, s, bucket, norm, g.rng);
        prop_assert!(q.n == n, "length");
        let d = q.dequantize();
        let mut off = 0;
        for b in &q.buckets {
            prop_assert!(
                b.levels.iter().all(|&l| l.unsigned_abs() <= s),
                "level exceeds s"
            );
            if b.scale == 0.0 {
                // degenerate bucket (zero or non-finite norm, e.g. L2
                // overflow on adversarial magnitudes): transmits all zeros
                prop_assert!(b.levels.iter().all(|&l| l == 0), "degenerate bucket nonzero");
                off += b.levels.len();
                continue;
            }
            let tol = b.scale / s as f32 + 1e-5;
            for i in 0..b.levels.len() {
                prop_assert!(
                    (d[off + i] - v[off + i]).abs() <= tol,
                    "error beyond one level at {}",
                    off + i
                );
                // sign preservation: a nonzero reconstruction keeps the sign
                if d[off + i] != 0.0 && v[off + i] != 0.0 {
                    prop_assert!(
                        (d[off + i] > 0.0) == (v[off + i] > 0.0),
                        "sign flipped"
                    );
                }
            }
            off += b.levels.len();
        }
        Ok(())
    });
}

#[test]
fn prop_deterministic_quantizer_lemma_f1() {
    forall("appendix-f", 150, 2000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = g.f32_vec(n);
        let q = deterministic::quantize(&v);
        let d = q.dequantize();
        let vnorm2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();
        let dot: f64 = v.iter().zip(&d).map(|(&a, &b)| a as f64 * b as f64).sum();
        prop_assert!(dot >= vnorm2 * 0.999, "vᵀQ(v) < ‖v‖²");
        prop_assert!(
            q.indices.len() as f64 <= (n as f64).sqrt() + 1.0,
            "|I(v)| > √n: {} vs {}",
            q.indices.len(),
            (n as f64).sqrt()
        );
        let bytes = q.encode();
        let q2 = deterministic::TopQuantized::decode(&bytes, n).map_err(|e| e.to_string())?;
        prop_assert!(q2 == q, "encode/decode mismatch");
        Ok(())
    });
}

#[test]
fn prop_plan_compressor_roundtrip_random_layouts() {
    forall("plan-compressor", 60, 8, |g| {
        // random layout of 1..6 tensors with mixed sizes
        let nt = g.usize_in(1, 6);
        let tensors: Vec<(String, Vec<usize>)> = (0..nt)
            .map(|i| {
                let big = g.bool();
                let size = if big { g.usize_in(200, 2000) } else { g.usize_in(1, 80) };
                (format!("t{i}"), vec![size])
            })
            .collect();
        let refs: Vec<(&str, Vec<usize>)> =
            tensors.iter().map(|(n, s)| (n.as_str(), s.clone())).collect();
        let layout = ParamLayout::synthetic(&refs);
        let n = layout.total_params();
        let plan = QuantPlan::build(&layout, 100);
        let grad = g.f32_vec(n);
        let specs = [
            CompressorSpec::Fp32,
            CompressorSpec::qsgd_4bit(),
            CompressorSpec::qsgd_2bit(),
            CompressorSpec::OneBit { column: 64 },
            CompressorSpec::TernGrad { bucket: 64 },
        ];
        let spec = &specs[g.usize_in(0, specs.len() - 1)];
        let pc = PlanCodec::from_spec(plan.clone(), spec);
        let seed = common::gen_seed(g);
        let msg = pc.session(Xoshiro256::from_u64(seed)).compress(&grad);
        let back = pc.decode(&msg, n).map_err(|e| e.to_string())?;
        prop_assert!(back.len() == n, "length");
        // fp32 segments must be bit-exact
        for seg in plan.segments.iter().filter(|s| !s.quantized) {
            for i in seg.offset..seg.offset + seg.len {
                prop_assert!(back[i] == grad[i], "fp32 segment not exact at {i}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encoded_size_beats_fp32_for_low_bits() {
    forall("wire-size", 40, 1, |g| {
        let n = 4096 + g.usize_in(0, 1000);
        let v = g.f32_vec(n);
        let seed = common::gen_seed(g);
        let m2 = CompressorSpec::qsgd_2bit()
            .codec()
            .session(Xoshiro256::from_u64(seed))
            .compress(&v);
        let m4 = CompressorSpec::qsgd_4bit()
            .codec()
            .session(Xoshiro256::from_u64(seed ^ 1))
            .compress(&v);
        prop_assert!(m2.len() * 8 < n * 4, "2-bit not <25% of fp32: {}", m2.len());
        prop_assert!(m4.len() * 6 < n * 4, "4-bit not well below fp32: {}", m4.len());
        prop_assert!(m2.len() < m4.len(), "2-bit must beat 4-bit on size");
        Ok(())
    });
}
