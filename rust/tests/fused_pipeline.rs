//! Tentpole invariants of the fused quantize→encode pipeline:
//!
//! * wire bytes **bit-identical** to the two-phase quantize-then-encode
//!   oracle across regimes (sparse/dense/auto), bucket sizes, norms and
//!   `s ∈ {1, 4, 15, 255}` — same RNG stream in, same bytes out;
//! * scratch reuse across many gradients of varying size never leaks state
//!   into the stream;
//! * `quantize_bucket` is statistically unbiased (Lemma 3.1(i) at the
//!   bucket level — the property the whole pipeline inherits).

mod common;

use qsgd::coding::gradient;
use qsgd::coding::gradient::Regime;
use qsgd::coding::{QsgdCodec, TwoPhaseQsgd};
use qsgd::coordinator::CompressorSpec;
use qsgd::prop_assert;
use qsgd::quant::{stochastic, Codec, EncodeSession, Norm};
use qsgd::util::check::forall;
use qsgd::util::rng::{self, Xoshiro256};

#[test]
fn prop_fused_wire_bytes_bit_identical_to_two_phase() {
    forall("fused-vs-two-phase", 140, 4000, |g| {
        let (n, bucket) = common::gen_dims(g);
        let v = common::gen_vec(g, n);
        let s = [1u32, 4, 15, 255][g.usize_in(0, 3)];
        let norm = common::gen_norm(g);
        let regime = common::gen_regime(g);
        let seed = (g.u32() as u64) << 16 | n as u64;
        let mut oracle = TwoPhaseQsgd::new(s, bucket, norm, regime)
            .session(Xoshiro256::from_u64(seed));
        let mut fused =
            QsgdCodec::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(seed));
        let a = oracle.compress(&v);
        let b = fused.compress(&v);
        prop_assert!(
            a == b,
            "wire bytes differ: n={n} s={s} bucket={bucket} {norm:?} {regime:?}"
        );
        // both frames decode to the same quantized gradient
        let qa = gradient::decode(&a).map_err(|e| e.to_string())?;
        prop_assert!(qa.n == n, "decoded length");
        Ok(())
    });
}

#[test]
fn prop_spec_built_fused_matches_two_phase_oracle() {
    // Through the coordinator's factory (the path the trainers take).
    forall("spec-fused-oracle", 60, 3000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let spec = [
            CompressorSpec::qsgd_2bit(),
            CompressorSpec::qsgd_4bit(),
            CompressorSpec::qsgd_8bit(),
        ][g.usize_in(0, 2)]
        .clone();
        let seed = g.u32() as u64;
        let fused_codec = spec.codec();
        let oracle_codec = spec.codec_two_phase();
        let a = fused_codec.session(Xoshiro256::from_u64(seed)).compress(&v);
        let b = oracle_codec.session(Xoshiro256::from_u64(seed)).compress(&v);
        prop_assert!(a == b, "{}: codec() and codec_two_phase() bytes differ", spec.label());
        // decode_add agreement on the same accumulator
        let mut acc_a = vec![0.5f32; n];
        let mut acc_b = vec![0.5f32; n];
        fused_codec.decode_add(&a, 0.25, &mut acc_a).map_err(|e| e.to_string())?;
        oracle_codec.decode_add(&b, 0.25, &mut acc_b).map_err(|e| e.to_string())?;
        prop_assert!(acc_a == acc_b, "decode-accumulate differs");
        Ok(())
    });
}

#[test]
fn fused_scratch_reuse_stays_bit_identical_across_varied_lengths() {
    let mut fused = QsgdCodec::new(7, 512, Norm::Max, None).session(Xoshiro256::from_u64(42));
    let mut oracle =
        TwoPhaseQsgd::new(7, 512, Norm::Max, None).session(Xoshiro256::from_u64(42));
    let mut data_rng = Xoshiro256::from_u64(1);
    // shrink after growing: stale scratch beyond the live prefix must never
    // leak into the frame
    for (round, base) in [0usize, 1, 5, 511, 512, 513, 6000, 100, 512, 3].iter().enumerate() {
        let n = base + round;
        let v: Vec<f32> = (0..n).map(|_| rng::normal_f32(&mut data_rng)).collect();
        let a = oracle.compress(&v);
        let b = fused.compress(&v);
        assert_eq!(a, b, "round {round} (n={n})");
    }
}

#[test]
fn fused_l2_and_forced_regimes_match_oracle() {
    // The streaming (static-regime) code path, explicitly.
    let mut data_rng = Xoshiro256::from_u64(2);
    let v: Vec<f32> = (0..5000).map(|_| rng::normal_f32(&mut data_rng)).collect();
    for (s, bucket, norm, regime) in [
        (1u32, usize::MAX, Norm::L2, None),          // paper §3.1, sparse rule
        (255, 256, Norm::L2, None),                  // dense rule
        (4, 512, Norm::Max, Some(Regime::Sparse)),   // forced sparse
        (4, 512, Norm::Max, Some(Regime::Dense)),    // forced dense
        (15, 64, Norm::L2, Some(Regime::Sparse)),
    ] {
        let mut oracle =
            TwoPhaseQsgd::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(7));
        let mut fused = QsgdCodec::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(7));
        let a = oracle.compress(&v);
        let b = fused.compress(&v);
        assert_eq!(a, b, "s={s} bucket={bucket} {norm:?} {regime:?}");
    }
}

#[test]
fn quantize_bucket_is_statistically_unbiased() {
    // Lemma 3.1(i) at bucket granularity: the mean of dequantized samples
    // converges to the input coordinate-wise, for both norms.
    let mut rng = Xoshiro256::from_u64(9);
    let v: Vec<f32> = (0..48).map(|_| rng::normal_f32(&mut rng)).collect();
    let s = 3u32;
    let trials = 6000usize;
    for norm in [Norm::L2, Norm::Max] {
        let mut acc = vec![0.0f64; v.len()];
        let mut out = vec![0.0f32; v.len()];
        for _ in 0..trials {
            let b = stochastic::quantize_bucket(&v, s, norm, &mut rng);
            b.dequantize_into(s, &mut out);
            for (a, &x) in acc.iter_mut().zip(&out) {
                *a += x as f64;
            }
        }
        let scale = norm.scale(&v) as f64;
        // per-coordinate stderr ≤ (scale/s)/(2·√trials); allow 10 stderr
        let tol = 5.0 * scale / (s as f64 * (trials as f64).sqrt());
        for (i, (&a, &x)) in acc.iter().zip(&v).enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - x as f64).abs() < tol,
                "{norm:?} coordinate {i} biased: mean {mean} vs {x} (tol {tol})"
            );
        }
    }
}
