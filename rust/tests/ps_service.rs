//! Acceptance suite for the sharded parameter-server service (`qsgd::ps`).
//!
//! Four properties carry the subsystem:
//!
//! 1. **Legacy golden** — the pre-refactor `coordinator::async_ps` loop is
//!    seeded-deterministic (final params bit-for-bit across reruns, fixed
//!    message/step accounting). The legacy code is kept untouched as the
//!    oracle, so the golden is a live rerun comparison rather than baked
//!    literals — any drift in its RNG streams or event ordering fails here
//!    before it can silently re-anchor the service parity below.
//! 2. **S=1 parity** — `ps::run_async` at one shard is bit-identical to the
//!    legacy loop: params, wire accounting, staleness, virtual time.
//! 3. **Router** — the QuantPlan-derived shard map is a total,
//!    non-overlapping partition for ragged dims and S ∈ {1, 2, 7}, and
//!    sharded push + pull(all) round-trips bit-identically to an unsharded
//!    decode of the same frames.
//! 4. **Service behaviour** — in-process and `uds:` socket runs land
//!    bit-identical final params; bursts past the queue depth shed
//!    (counted, never a hang); pushes older than τ are rejected with the
//!    stale count visible in metrics.

use std::sync::Arc;
use std::time::Duration;

use qsgd::coordinator::async_ps::{self, AsyncConfig};
use qsgd::coordinator::sources::ConvexSource;
use qsgd::coordinator::CompressorSpec;
use qsgd::data::QuadraticProblem;
use qsgd::models::layout::{ParamLayout, QuantPlan};
use qsgd::models::CostModel;
use qsgd::ps::{self, Service, ServiceConfig, ShardMap, Target, TrafficConfig};
use qsgd::simnet::{Link, SimNet, Topology};
use qsgd::transport::Endpoint;
use qsgd::util::rng::{self, Xoshiro256};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn async_cfg(workers: usize, updates: usize, compressor: CompressorSpec) -> AsyncConfig {
    AsyncConfig {
        workers,
        updates,
        compressor,
        lr: 0.02,
        seed: 1,
        net: SimNet::new(workers, Link::new(1e9, 1e-5), Topology::Star),
        cost: CostModel::k80(),
        speed: vec![],
        log_every: 10,
    }
}

fn async_source() -> ConvexSource<QuadraticProblem> {
    ConvexSource::new(QuadraticProblem::generate(256, 24, 1e-3, 0.05, 11), 8, 13)
}

fn mk_service(n: usize, shards: usize, staleness: Option<u64>, depth: usize) -> Service {
    let cfg = ServiceConfig {
        compressor: CompressorSpec::qsgd_4bit(),
        lr: 0.05,
        seed: 7,
        staleness,
        queue_depth: depth,
    };
    Service::new(ShardMap::uniform(n, shards).unwrap(), &cfg)
}

fn uds_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("qsgd-ps-{}-{tag}.sock", std::process::id()))
}

// ---------------------------------------------------------------------------
// 1. Legacy determinism golden (satellite: pinned before the refactor).
// ---------------------------------------------------------------------------

#[test]
fn legacy_async_ps_seeded_golden() {
    let run = || {
        async_ps::run(&async_cfg(4, 300, CompressorSpec::qsgd_4bit()), &mut async_source()).unwrap()
    };
    let r1 = run();
    let r2 = run();
    // Final params to_bits: exact across reruns at the same seed.
    assert_eq!(bits(&r1.params), bits(&r2.params), "legacy async_ps must be seeded-deterministic");
    assert_eq!(r1.vtime.to_bits(), r2.vtime.to_bits());
    assert_eq!(r1.max_staleness, r2.max_staleness);
    // Step-count accounting: one applied push per update, logged every 10.
    assert_eq!(r1.wire.messages, 300);
    // Source dim is 24 (QuadraticProblem::generate(m=256, dim=24, ..)).
    assert_eq!(r1.wire.fp32_equiv_bytes, 300 * 24 * 4);
    assert_eq!(r1.loss.points.len(), 30);
    assert_eq!(r1.loss.points.last().unwrap().0, 300);
}

// ---------------------------------------------------------------------------
// 2. S=1 service path bit-identical to the legacy loop.
// ---------------------------------------------------------------------------

#[test]
fn s1_service_bit_identical_to_legacy_qsgd() {
    let cfg = async_cfg(4, 300, CompressorSpec::qsgd_4bit());
    let legacy = async_ps::run(&cfg, &mut async_source()).unwrap();
    let svc = ps::run_async(&cfg, &mut async_source(), 1).unwrap();
    assert_eq!(bits(&legacy.params), bits(&svc.params), "S=1 params must match legacy bit-for-bit");
    assert_eq!(legacy.vtime.to_bits(), svc.vtime.to_bits());
    assert_eq!(legacy.wire.messages, svc.wire.messages);
    assert_eq!(legacy.wire.payload_bytes, svc.wire.payload_bytes);
    assert_eq!(legacy.wire.fp32_equiv_bytes, svc.wire.fp32_equiv_bytes);
    assert_eq!(legacy.max_staleness, svc.max_staleness);
    assert_eq!(legacy.mean_staleness.to_bits(), svc.mean_staleness.to_bits());
    assert_eq!(legacy.loss.points, svc.loss.points);
}

#[test]
fn s1_service_bit_identical_to_legacy_nuqsgd_and_fp32() {
    // The parity is codec-independent: v2 non-uniform frames and raw fp32
    // ride the same event schedule and the same session streams.
    for spec in [CompressorSpec::nuqsgd_4bit(), CompressorSpec::Fp32] {
        let cfg = async_cfg(3, 150, spec.clone());
        let legacy = async_ps::run(&cfg, &mut async_source()).unwrap();
        let svc = ps::run_async(&cfg, &mut async_source(), 1).unwrap();
        assert_eq!(bits(&legacy.params), bits(&svc.params), "parity broke for {}", spec.label());
        assert_eq!(legacy.wire.payload_bytes, svc.wire.payload_bytes);
        assert_eq!(legacy.vtime.to_bits(), svc.vtime.to_bits());
    }
}

#[test]
fn sharded_async_run_still_converges() {
    // S>1 is a different (per-shard) quantization of the same gradients —
    // not bit-equal to S=1, but it must still train.
    let cfg = async_cfg(4, 400, CompressorSpec::qsgd_4bit());
    let r = ps::run_async(&cfg, &mut async_source(), 4).unwrap();
    let first = r.loss.points[0].1;
    let last = r.loss.tail_mean(3);
    assert!(last < first * 0.3, "sharded async diverged: {first} -> {last}");
    assert_eq!(r.wire.messages, 400, "one recorded push event per update");
}

// ---------------------------------------------------------------------------
// 3. Router property tests: partition + sharded/unsharded round-trip.
// ---------------------------------------------------------------------------

#[test]
fn shard_map_is_total_nonoverlapping_partition_for_ragged_dims() {
    // Ragged synthetic layout: mixed tensors, some below the quantization
    // threshold (fp32), one above (quantized).
    let layout = ParamLayout::synthetic(&[
        ("bias", vec![7]),
        ("blocks", vec![13, 3]),
        ("emb", vec![101]),
    ]);
    let plan = QuantPlan::build(&layout, 40);
    let total = plan.total_len();
    assert_eq!(total, 7 + 39 + 101);
    for s_count in [1usize, 2, 7] {
        let map = ShardMap::build(&plan, s_count).unwrap();
        assert_eq!(map.num_shards(), s_count);
        assert_eq!(map.total_len(), total);
        // Contiguous cover: offsets chain exactly, lens sum to total.
        let mut cursor = 0usize;
        for r in map.shards() {
            assert_eq!(r.offset, cursor, "gap/overlap at shard {}", r.index);
            assert_eq!(r.plan.total_len(), r.len, "shard plan must cover its range");
            cursor += r.len;
        }
        assert_eq!(cursor, total);
        // Every coordinate resolves to the shard whose range contains it,
        // and carries the same quantized flag as the original plan.
        let flag_of = |coord: usize, plan: &QuantPlan| -> bool {
            plan.segments
                .iter()
                .find(|seg| coord >= seg.offset && coord < seg.offset + seg.len)
                .map(|seg| seg.quantized)
                .expect("coord covered")
        };
        for coord in 0..total {
            let s = map.shard_of(coord).expect("total partition");
            let r = map.shard(s);
            assert!(coord >= r.offset && coord < r.offset + r.len);
            assert_eq!(
                flag_of(coord, &r.plan),
                flag_of(coord, &plan),
                "quantized flag drifted at coord {coord}, S={s_count}"
            );
        }
        assert_eq!(map.shard_of(total), None);
    }
}

#[test]
fn sharded_push_round_trips_bit_identically_to_unsharded_decode() {
    let n = 1100usize;
    let grad = rng::normal_vec(&mut Xoshiro256::from_u64(21), n);
    for s_count in [1usize, 2, 7] {
        let svc = mk_service(n, s_count, None, 8);
        let codec = svc.codec().clone();
        let init = svc.dense_params();
        // One frame per shard, sessions derived per shard.
        let frames: Vec<Vec<u8>> = (0..s_count)
            .map(|s| {
                let r = svc.map().shard(s);
                codec.session(Xoshiro256::stream(123, s as u64)).compress(r.slice(&grad))
            })
            .collect();
        // Reference: apply the SAME frames to the corresponding slices of an
        // unsharded copy via the plain decode_add path.
        let mut reference = init.clone();
        for (s, frame) in frames.iter().enumerate() {
            let r = svc.map().shard(s);
            codec
                .decode_add(frame, -0.05, &mut reference[r.offset..r.offset + r.len])
                .unwrap();
        }
        // Service: push each frame, then pull(all) shards back together.
        for (s, frame) in frames.iter().enumerate() {
            assert_eq!(svc.push(s, 0, frame).unwrap(), ps::Reply::Pushed { version: 1 });
        }
        let mut pulled = vec![0.0f32; n];
        let mut out = Vec::new();
        for s in 0..s_count {
            assert_eq!(svc.pull_dense(s, &mut out), Some(1));
            let r = svc.map().shard(s);
            pulled[r.offset..r.offset + r.len].copy_from_slice(&out);
        }
        assert_eq!(
            bits(&pulled),
            bits(&reference),
            "sharded push+pull(all) != unsharded decode at S={s_count}"
        );
        assert_eq!(bits(&svc.dense_params()), bits(&reference));
    }
}

// ---------------------------------------------------------------------------
// 4. Service behaviour: socket parity, shedding, staleness.
// ---------------------------------------------------------------------------

#[test]
fn s4_socket_and_in_process_runs_agree_bit_for_bit() {
    let tcfg = TrafficConfig {
        clients: 3,
        threads: 1, // single-threaded ⇒ one deterministic op sequence
        ops: 400,
        push_fraction: 0.8,
        zipf: 1.2,
        burst: 8,
        seed: 17,
    };
    let svc_local = mk_service(4096, 4, None, 64);
    let rep_local = ps::run_traffic(&svc_local, Target::InProcess, &tcfg).unwrap();

    let svc_sock = Arc::new(mk_service(4096, 4, None, 64));
    let path = uds_path("parity");
    let _ = std::fs::remove_file(&path);
    let server = ps::serve(&Endpoint::Uds(path.clone()), svc_sock.clone()).unwrap();
    let rep_sock = ps::run_traffic(&svc_sock, Target::Socket(server.endpoint()), &tcfg).unwrap();
    server.shutdown();
    let _ = std::fs::remove_file(&path);

    assert_eq!(
        bits(&svc_local.dense_params()),
        bits(&svc_sock.dense_params()),
        "uds socket run must land the exact parameters the in-process run does"
    );
    assert_eq!(
        (rep_local.pushed_ok, rep_local.pulls_ok, rep_local.stale, rep_local.shed),
        (rep_sock.pushed_ok, rep_sock.pulls_ok, rep_sock.stale, rep_sock.shed),
        "op accounting must match across transports"
    );
    assert_eq!(rep_local.shed, 0, "deep queues: nothing shed in either mode");
}

#[test]
fn burst_past_queue_depth_sheds_counted_and_returns() {
    let depth = 2usize;
    let svc = mk_service(2048, 2, None, depth);
    // Deterministic overload: fill every shard's admission gate, exactly
    // depth permits each (no extra try_enter calls — those would count as
    // shed themselves).
    let mut permits = Vec::new();
    for s in 0..svc.num_shards() {
        for _ in 0..depth {
            permits.push(svc.admission(s).try_enter().expect("gate not yet full"));
        }
    }
    let tcfg = TrafficConfig {
        clients: 4,
        threads: 1,
        ops: 100,
        push_fraction: 0.7,
        zipf: 1.0,
        burst: 16,
        seed: 3,
    };
    let rep = ps::run_traffic(&svc, Target::InProcess, &tcfg).unwrap();
    assert_eq!(rep.ops, 100, "every op completed with a response — no hang");
    assert_eq!(rep.shed, 100, "full gates shed the entire burst");
    assert_eq!((rep.pushed_ok, rep.pulls_ok, rep.stale), (0, 0, 0));
    assert_eq!(svc.metrics().shed, 100);
    drop(permits);
    // Gates released: the same traffic now goes through untouched.
    let rep2 = ps::run_traffic(&svc, Target::InProcess, &tcfg).unwrap();
    assert_eq!(rep2.shed, 0);
    assert_eq!(rep2.pushed_ok + rep2.pulls_ok, 100);
}

#[test]
fn concurrent_overload_never_hangs_and_conserves_ops() {
    // Genuine contention: shallow gates, hot Zipf head, many threads. Shed
    // counts are timing-dependent; conservation and completion are not.
    let svc = mk_service(8192, 4, None, 1);
    let tcfg = TrafficConfig {
        clients: 8,
        threads: 4,
        ops: 2000,
        push_fraction: 0.8,
        zipf: 2.5,
        burst: 32,
        seed: 11,
    };
    let rep = ps::run_traffic(&svc, Target::InProcess, &tcfg).unwrap();
    assert_eq!(rep.ops, 2000);
    assert_eq!(rep.pushed_ok + rep.pulls_ok + rep.stale + rep.shed, rep.ops);
    let m = svc.metrics();
    assert_eq!(m.shed, rep.shed, "service and client agree on shed count");
    assert_eq!(m.pushes, rep.pushed_ok);
}

#[test]
fn stale_push_rejected_over_socket_with_metrics() {
    use qsgd::ps::service::{
        encode_request, parse_response, OP_PUSH, ST_OK, ST_STALE,
    };
    use qsgd::transport::frame::{write_frame, FrameReader};

    let svc = Arc::new(mk_service(512, 1, Some(0), 8));
    let codec = svc.codec().clone();
    let path = uds_path("stale");
    let _ = std::fs::remove_file(&path);
    let server = ps::serve(&Endpoint::Uds(path.clone()), svc.clone()).unwrap();
    {
        let mut conn =
            qsgd::transport::connect_retry(server.endpoint(), Duration::from_secs(5)).unwrap();
        conn.set_timeouts(Some(Duration::from_secs(5))).unwrap();
        let mut reader = FrameReader::new();
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(5), 512);
        let mut sess = codec.session(Xoshiro256::from_u64(6));
        let mut req = Vec::new();

        // Fresh push at the shard's current version: applied.
        encode_request(&mut req, OP_PUSH, 0, 9, 0, &sess.compress(&grad));
        write_frame(&mut conn, &req).unwrap();
        let resp = parse_response(reader.read_frame(&mut conn).unwrap().unwrap()).unwrap();
        assert_eq!((resp.status, resp.version), (ST_OK, 1));

        // Same pulled version again: τ=0 means any lag is too old.
        encode_request(&mut req, OP_PUSH, 0, 9, 0, &sess.compress(&grad));
        write_frame(&mut conn, &req).unwrap();
        let resp = parse_response(reader.read_frame(&mut conn).unwrap().unwrap()).unwrap();
        assert_eq!((resp.status, resp.version), (ST_STALE, 1), "stale push must be rejected");
    }
    server.shutdown();
    let _ = std::fs::remove_file(&path);
    let m = svc.metrics();
    assert_eq!(m.stale_rejected, 1, "stale count must surface in metrics");
    assert_eq!(m.pushes, 1);
    assert_eq!(svc.shard_version(0), 1, "rejected push must not advance the version");
}
