//! Trait-level conformance suite for the session-based codec API, run over
//! every [`CompressorSpec`] arm (fp32, QSGD 2/4/8-bit, NUQSGD, 1BitSGD,
//! TernGrad) plus the plan codec:
//!
//! * round-trip: `session.encode_into` → `Codec::decode` returns the right
//!   length, and `decode_add` agrees with decode-then-accumulate;
//! * **zero-allocation steady state**: repeated `encode_into` into a reused
//!   buffer touches the heap exactly zero times once warm — for every arm,
//!   not just the fused QSGD pipeline (counting global allocator with a
//!   thread-local counter, so concurrently running tests don't pollute it);
//! * `decode_add_threads` is **bit-identical** across thread budgets
//!   {1, 2, 8};
//! * truncated messages are rejected by every arm, and garbage (clobbered
//!   magic) by the self-describing frame arms;
//! * sessions are deterministic in their seed, and `encoded_size_hint`
//!   upper-bounds the measured message for the max-norm arms (exactly for
//!   the fixed-rate ones).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use qsgd::coordinator::exchange::PlanCodec;
use qsgd::coordinator::CompressorSpec;
use qsgd::models::layout::{ParamLayout, QuantPlan};
use qsgd::quant::{Codec, EncodeSession, Norm, WireFormat};
use qsgd::util::rng::{self, Xoshiro256};

// ---------------------------------------------------------------------------
// Thread-local counting allocator
// ---------------------------------------------------------------------------

struct CountingAlloc;

std::thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocations made by *this* thread so far.
fn local_allocs() -> u64 {
    LOCAL_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

/// Every compressor arm the coordinators can be configured with.
fn all_specs() -> Vec<CompressorSpec> {
    vec![
        CompressorSpec::Fp32,
        CompressorSpec::qsgd_2bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::nuqsgd_4bit(),
        CompressorSpec::Nuqsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None },
        CompressorSpec::OneBit { column: 512 },
        CompressorSpec::TernGrad { bucket: 512 },
    ]
}

/// Large enough that the QSGD arms emit the v3 bucket-offset directory
/// (≥ 2^16 coords), so the threaded decode paths genuinely engage.
const N: usize = 80_000;

fn gradient(seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256::from_u64(seed);
    rng::normal_vec(&mut r, N)
}

// ---------------------------------------------------------------------------
// Conformance properties
// ---------------------------------------------------------------------------

#[test]
fn round_trip_and_decode_add_agree_for_every_arm() {
    let grad = gradient(1);
    for spec in all_specs() {
        let codec = spec.codec();
        let msg = codec.session(Xoshiro256::from_u64(2)).compress(&grad);
        let dec = codec.decode(&msg, N).unwrap_or_else(|e| panic!("{}: {e}", spec.label()));
        assert_eq!(dec.len(), N, "{}", spec.label());
        let mut acc = vec![0.125f32; N];
        codec.decode_add(&msg, 0.5, &mut acc).unwrap();
        for (i, (a, &x)) in acc.iter().zip(&dec).enumerate() {
            let want = 0.125 + 0.5 * x;
            assert!(
                (a - want).abs() <= 1e-6 * want.abs().max(1.0),
                "{}: decode_add diverges at {i}: {a} vs {want}",
                spec.label()
            );
        }
        // sessions are deterministic in their seed
        let again = codec.session(Xoshiro256::from_u64(2)).compress(&grad);
        assert_eq!(msg, again, "{}: same seed, different bytes", spec.label());
        // the no-encode size estimate upper-bounds the measured message
        // (all default arms are max-norm / fixed-rate, where the hint is a
        // worst-case or exact figure)
        let hint = codec.encoded_size_hint(N);
        assert!(
            msg.len() <= hint,
            "{}: measured {} > hint {hint}",
            spec.label(),
            msg.len()
        );
        // wire-format metadata matches the arm family
        let wf = codec.wire_format();
        match &spec {
            CompressorSpec::Fp32 => assert_eq!(wf, WireFormat::RawF32),
            CompressorSpec::Qsgd { .. } | CompressorSpec::Nuqsgd { .. } => {
                assert!(matches!(wf, WireFormat::EliasFrame { .. }), "{}", spec.label())
            }
            CompressorSpec::OneBit { column } => {
                assert_eq!(wf, WireFormat::SignColumns { column: *column })
            }
            CompressorSpec::TernGrad { bucket } => {
                assert_eq!(wf, WireFormat::Ternary { bucket: *bucket })
            }
        }
    }
}

#[test]
fn encode_into_steady_state_is_allocation_free_for_every_arm() {
    let grad = gradient(3);
    for spec in all_specs() {
        let codec = spec.codec();
        let mut sess = codec.session(Xoshiro256::from_u64(4));
        let mut out = Vec::with_capacity(codec.encoded_size_hint(N));
        // Warm: grow the session scratch and the output buffer to steady
        // state (message sizes vary slightly with the RNG draw, so warm a
        // few times — the same policy the coding_hotpath bench enforces).
        for _ in 0..3 {
            sess.encode_into(&grad, &mut out);
        }
        let before = local_allocs();
        for _ in 0..8 {
            sess.encode_into(&grad, &mut out);
        }
        let allocs = local_allocs() - before;
        assert_eq!(
            allocs,
            0,
            "{}: {allocs} steady-state allocations over 8 encode_into calls",
            spec.label()
        );
        assert!(!out.is_empty());
    }
}

#[test]
fn plan_session_steady_state_is_allocation_free() {
    // The segment container composes inner sessions; its staging scratch
    // and the inner sessions' buffers must all reach steady state too.
    let layout = ParamLayout::synthetic(&[
        ("small", vec![100]), // fp32 skip segment
        ("big", vec![400, 180]),
        ("bias", vec![60]),
    ]);
    let plan = QuantPlan::build(&layout, 10_000);
    let n = layout.total_params();
    let mut r = Xoshiro256::from_u64(5);
    let grad = rng::normal_vec(&mut r, n);
    let specs =
        [CompressorSpec::qsgd_4bit(), CompressorSpec::Fp32, CompressorSpec::OneBit { column: 512 }];
    for spec in specs {
        let pc = PlanCodec::from_spec(plan.clone(), &spec);
        let mut sess = pc.session(Xoshiro256::from_u64(6));
        let mut out = Vec::with_capacity(pc.encoded_size_hint(n));
        for _ in 0..3 {
            sess.encode_into(&grad, &mut out);
        }
        let before = local_allocs();
        for _ in 0..8 {
            sess.encode_into(&grad, &mut out);
        }
        let allocs = local_allocs() - before;
        assert_eq!(allocs, 0, "plan over {}: {allocs} steady-state allocations", spec.label());
        // and the framed message still decodes
        assert_eq!(pc.decode(&out, n).unwrap().len(), n);
    }
}

#[test]
fn decode_add_threads_is_bit_identical_at_every_budget() {
    let grad = gradient(7);
    for spec in all_specs() {
        let codec = spec.codec();
        let msg = codec.session(Xoshiro256::from_u64(8)).compress(&grad);
        let mut base = vec![0.25f32; N];
        codec.decode_add_threads(&msg, 0.5, &mut base, 1).unwrap();
        for threads in [2usize, 8] {
            let mut acc = vec![0.25f32; N];
            codec.decode_add_threads(&msg, 0.5, &mut acc, threads).unwrap();
            let same = acc.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{}: budget {threads} diverged from serial", spec.label());
        }
    }
}

#[test]
fn truncation_is_rejected_by_every_arm() {
    let grad = gradient(9);
    for spec in all_specs() {
        let codec = spec.codec();
        let msg = codec.session(Xoshiro256::from_u64(10)).compress(&grad);
        for cut in [0usize, 1, msg.len() / 2, msg.len() - 1] {
            assert!(
                codec.decode(&msg[..cut], N).is_err(),
                "{}: decode of {cut}/{} bytes accepted",
                spec.label(),
                msg.len()
            );
            let mut acc = vec![0.0f32; N];
            assert!(
                codec.decode_add(&msg[..cut], 1.0, &mut acc).is_err(),
                "{}: decode_add of truncation at {cut} accepted",
                spec.label()
            );
            assert!(
                codec.decode_add_threads(&msg[..cut], 1.0, &mut acc, 4).is_err(),
                "{}: threaded decode_add of truncation at {cut} accepted",
                spec.label()
            );
        }
    }
}

#[test]
fn garbage_is_rejected_by_the_self_describing_arms() {
    // Headerless formats (fp32/1bit/terngrad) cannot detect payload bit
    // flips by design; the Elias frame arms carry magic + version and must
    // reject a clobbered header outright.
    let grad = gradient(11);
    for spec in [
        CompressorSpec::qsgd_2bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::nuqsgd_4bit(),
    ] {
        let codec = spec.codec();
        let mut msg = codec.session(Xoshiro256::from_u64(12)).compress(&grad);
        msg[0] ^= 0xff; // magic
        assert!(codec.decode(&msg, N).is_err(), "{}: bad magic accepted", spec.label());
        let mut acc = vec![0.0f32; N];
        assert!(codec.decode_add(&msg, 1.0, &mut acc).is_err(), "{}", spec.label());
        // arbitrary bytes without the frame magic never panic, never decode
        let mut r = Xoshiro256::from_u64(13);
        let mut junk = rng::normal_vec(&mut r, 256)
            .iter()
            .map(|x| x.to_bits() as u8)
            .collect::<Vec<u8>>();
        junk[0] = 0x00; // definitely not FRAME_MAGIC
        assert!(codec.decode(&junk, N).is_err(), "{}", spec.label());
    }
}
