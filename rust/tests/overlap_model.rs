//! Properties of the §5-style overlap model across the full arm × topology
//! matrix.
//!
//! The schedule-derived overlapped epoch time
//! ([`EpochSim::epoch_time_overlapped`]) must behave like a *pipeline*, not
//! a fudge factor, for every compressor and collective the simulator
//! supports:
//!
//! * **Bounds** — overlap can hide communication behind computation but
//!   cannot invent time: `max(comp, comm) ≤ overlapped(φ) ≤ serial` for all
//!   φ ∈ [0, 1].
//! * **Monotonicity** — more overlap never hurts: φ ↦ overlapped(φ) is
//!   non-increasing.
//! * **Exact serial endpoint** — φ = 0 reproduces [`EpochSim::epoch_time`]
//!   bit for bit (`to_bits`), so reports that omit `--overlap-fraction`
//!   are untouched by this feature.

use qsgd::config::CollectiveSpec;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm, EpochSim};
use qsgd::models::{zoo, CostModel, NetworkShape};
use qsgd::simnet::{Preset, SimNet};

const PHI_GRID: [f64; 6] = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0];

fn arms() -> Vec<EpochArm> {
    let collectives = [
        CollectiveSpec::AllToAll,
        CollectiveSpec::ring(),
        CollectiveSpec::ring_ef(),
        CollectiveSpec::parse("ring:raw").unwrap(),
        CollectiveSpec::hierarchical(4),
    ];
    let mut arms = vec![EpochArm::fp32(), EpochArm::fp32_allreduce()];
    for c in collectives {
        arms.push(EpochArm::qsgd(4, 512).with_collective(c.clone()));
        arms.push(EpochArm::nuqsgd(4, 512).with_collective(c));
    }
    arms
}

fn networks() -> Vec<NetworkShape> {
    vec![zoo::alexnet(), zoo::resnet50(), zoo::lstm_an4()]
}

fn sim(net: &NetworkShape, gpus: usize, arm: &EpochArm) -> EpochSim {
    let simnet = SimNet::preset(gpus, Preset::K80Pcie);
    simulate_epoch(net, gpus, arm, &simnet, &CostModel::k80(), 1, 0)
}

/// Relative slack for the floating-point comparisons: the schedule folds
/// hundreds of per-tensor terms, so exact ordering can wobble in the last
/// ulp even though the model is monotone.
fn eps(scale: f64) -> f64 {
    1e-9 * scale.max(1.0)
}

#[test]
fn overlapped_time_is_bounded_by_serial_and_critical_path() {
    for net in networks() {
        for gpus in [4usize, 16] {
            for arm in arms() {
                let r = sim(&net, gpus, &arm);
                assert!(!r.schedule.is_empty(), "{}: empty schedule", net.name);
                let serial = r.epoch_time();
                let comp = r.breakdown.compute.secs();
                let comm = r.breakdown.communication().secs();
                let floor = comp.max(comm);
                for phi in PHI_GRID {
                    let t = r.epoch_time_overlapped(phi);
                    let tag = format!("{} {}×{} {} φ={phi}", net.name, gpus, r.arm, r.collective);
                    assert!(t <= serial + eps(serial), "{tag}: {t} above serial {serial}");
                    assert!(t >= floor - eps(serial), "{tag}: {t} below floor {floor}");
                }
            }
        }
    }
}

#[test]
fn overlapped_time_is_monotone_in_fraction() {
    for net in networks() {
        for arm in arms() {
            let r = sim(&net, 8, &arm);
            let serial = r.epoch_time();
            let mut prev = f64::INFINITY;
            for phi in PHI_GRID {
                let t = r.epoch_time_overlapped(phi);
                assert!(
                    t <= prev + eps(serial),
                    "{} {} {}: overlapped({phi}) = {t} above previous {prev}",
                    net.name,
                    r.arm,
                    r.collective
                );
                prev = t;
            }
        }
    }
}

#[test]
fn zero_overlap_reproduces_serial_epoch_time_exactly() {
    // Not "close": bit-identical. φ = 0 must take the same code path sums
    // as the stacked-bar total so existing goldens and reports are inert.
    for net in networks() {
        for gpus in [2usize, 8] {
            for arm in arms() {
                let r = sim(&net, gpus, &arm);
                assert_eq!(
                    r.epoch_time_overlapped(0.0).to_bits(),
                    r.epoch_time().to_bits(),
                    "{} {}×{} {}: φ=0 diverged from epoch_time()",
                    net.name,
                    gpus,
                    r.arm,
                    r.collective
                );
            }
        }
    }
}

#[test]
fn full_overlap_helps_a_comm_bound_arm_and_respects_the_floor() {
    // 16-GPU fp32 AlexNet is >70% communication: full per-layer bucket
    // readiness must shrink the epoch, and a compute-bound arm (ResNet-50,
    // 4-bit ring on 4 GPUs) must pin near max(comp, comm) rather than dip
    // below it.
    let comm_bound = sim(&zoo::alexnet(), 16, &EpochArm::fp32());
    assert!(
        comm_bound.epoch_time_overlapped(1.0) < comm_bound.epoch_time(),
        "full overlap should shrink a comm-bound epoch"
    );

    let compute_bound =
        sim(&zoo::resnet50(), 4, &EpochArm::qsgd(4, 512).with_collective(CollectiveSpec::ring()));
    let comp = compute_bound.breakdown.compute.secs();
    let comm = compute_bound.breakdown.communication().secs();
    assert!(comp > comm, "expected a compute-bound configuration");
    let full = compute_bound.epoch_time_overlapped(1.0);
    assert!(full >= comp - eps(comp), "overlap must not hide computation: {full} < {comp}");
}
