//! Collective-algorithms conformance suite: determinism goldens (fixed seed
//! ⇒ identical final aggregate bits across runs and across decode thread
//! budgets {1, 2, 8} — the in-process stand-in for `QSGD_THREADS`, which the
//! codec thread budget honours), the ring-without-recompression ≡ all-to-all
//! mean bit-identity property, traffic ordering (recompressing ring moves
//! strictly fewer bytes than all-to-all at K=16), error-feedback behaviour,
//! and the zero-steady-state-allocation invariant of the ring's hop
//! re-encode path (counting global allocator with a thread-local counter).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use qsgd::collectives::{self, AllToAll, CollectiveAlgo, Hierarchical, RingAllreduce};
use qsgd::config::{CodecOptions, CollectiveSpec};
use qsgd::coordinator::CompressorSpec;
use qsgd::quant::Codec;
use qsgd::simnet::{Link, SimNet, Topology};
use qsgd::util::rng::{self, Xoshiro256};

// ---------------------------------------------------------------------------
// Thread-local counting allocator (same pattern as codec_conformance.rs)
// ---------------------------------------------------------------------------

struct CountingAlloc;

std::thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn local_allocs() -> u64 {
    LOCAL_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

fn net(k: usize) -> SimNet {
    SimNet::new(k, Link::new(3.5e9, 50e-6), Topology::P2pBroadcast)
}

fn grads(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..k)
        .map(|w| {
            let mut r = Xoshiro256::stream(seed, w as u64);
            rng::normal_vec(&mut r, n)
        })
        .collect()
}

fn all_collectives() -> Vec<CollectiveSpec> {
    vec![
        CollectiveSpec::AllToAll,
        CollectiveSpec::ring(),
        CollectiveSpec::ring_ef(),
        CollectiveSpec::Ring { recompress: false, error_feedback: false },
        CollectiveSpec::hierarchical(4),
        CollectiveSpec::hierarchical(3), // ragged groups at k=8
    ]
}

/// Run `steps` exchanges of fixed gradients through a fresh algorithm built
/// with the given codec; returns the final mean and the cumulative wire
/// payload bytes.
fn run_algo(
    spec: &CollectiveSpec,
    codec: Arc<dyn Codec>,
    k: usize,
    n: usize,
    steps: usize,
    seed: u64,
) -> (Vec<f32>, u64, collectives::Exchange) {
    let g = grads(k, n, 99);
    let mut algo = collectives::build(spec, codec, k, seed);
    algo.prepare(n);
    let mut mean = Vec::new();
    let mut payload = 0u64;
    let mut last = collectives::Exchange::default();
    for _ in 0..steps {
        last = algo.exchange(&net(k), &g, &mut mean).unwrap();
        payload += last.wire.payload_bytes;
    }
    (mean, payload, last)
}

// ---------------------------------------------------------------------------
// Determinism goldens
// ---------------------------------------------------------------------------

#[test]
fn fixed_seed_reproduces_aggregate_bits_across_runs() {
    let k = 8;
    let n = 3 * 512 * 8 + 123; // ragged tail exercises short/empty segments
    for spec in all_collectives() {
        let c = CompressorSpec::qsgd_4bit();
        let (m1, b1, x1) = run_algo(&spec, c.codec(), k, n, 3, 7);
        let (m2, b2, x2) = run_algo(&spec, c.codec(), k, n, 3, 7);
        assert_eq!(m1, m2, "{}: aggregate bits must be seed-deterministic", spec.label());
        assert_eq!(b1, b2, "{}: wire bytes must be seed-deterministic", spec.label());
        assert_eq!(x1.hops, x2.hops, "{}", spec.label());
        assert_eq!(x1.recompressions, x2.recompressions, "{}", spec.label());
        // a different seed moves the quantization randomness
        let (m3, _, _) = run_algo(&spec, c.codec(), k, n, 3, 8);
        assert_ne!(m1, m3, "{}: seed must matter", spec.label());
    }
}

#[test]
fn aggregate_bits_identical_across_thread_budgets() {
    // The codec decode thread budget is the configured face of
    // `QSGD_THREADS`; the Codec contract promises bit-identical
    // accumulators at every budget, and no algorithm may break it.
    let k = 8;
    let n = 2 * 512 * 8;
    for spec in all_collectives() {
        let reference = {
            let codec = CompressorSpec::qsgd_4bit()
                .codec_with(CodecOptions { threads: Some(1), ..CodecOptions::default() });
            run_algo(&spec, codec, k, n, 2, 11).0
        };
        for budget in [2usize, 8] {
            let codec = CompressorSpec::qsgd_4bit().codec_with(CodecOptions {
                threads: Some(budget),
                ..CodecOptions::default()
            });
            let (m, _, _) = run_algo(&spec, codec, k, n, 2, 11);
            assert_eq!(
                m,
                reference,
                "{}: thread budget {budget} changed the aggregate bits",
                spec.label()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Ring-without-recompression ≡ all-to-all mean (property)
// ---------------------------------------------------------------------------

#[test]
fn ring_without_recompression_matches_all_to_all_mean() {
    // Segments are bucket-aligned and each worker's single session encodes
    // its segments in order, so the quantized levels equal a whole-gradient
    // pass; the ring then only transports the original frames, and the
    // local reduction accumulates in worker order — the all-to-all order.
    let k = 8;
    for (n, seed) in [(3 * 512 * 8, 1u64), (3 * 512 * 8 + 123, 2), (2048, 3), (640, 4)] {
        let spec = CompressorSpec::qsgd_4bit();
        let g = grads(k, n, seed);
        let mut a2a = AllToAll::new(spec.codec(), k, 42);
        let mut raw = RingAllreduce::new(spec.codec(), k, 42, false, false);
        let (mut m1, mut m2) = (Vec::new(), Vec::new());
        let x1 = a2a.exchange(&net(k), &g, &mut m1).unwrap();
        let x2 = raw.exchange(&net(k), &g, &mut m2).unwrap();
        assert_eq!(m1, m2, "n={n}: ring:raw must be bit-identical to the a2a mean");
        // pure transport: no recompression on either side
        assert_eq!(x1.recompressions, 0);
        assert_eq!(x2.recompressions, 0);
        assert_eq!(x2.recompress_err_sq, 0.0);
    }
}

#[test]
fn nuqsgd_ring_raw_matches_all_to_all_too() {
    // The property is grid-independent: the exponential-grid codec rides
    // the same aligned-segment argument.
    let k = 4;
    let n = 2 * 512 * 4 + 17;
    let spec = CompressorSpec::nuqsgd_4bit();
    let g = grads(k, n, 5);
    let mut a2a = AllToAll::new(spec.codec(), k, 21);
    let mut raw = RingAllreduce::new(spec.codec(), k, 21, false, false);
    let (mut m1, mut m2) = (Vec::new(), Vec::new());
    a2a.exchange(&net(k), &g, &mut m1).unwrap();
    raw.exchange(&net(k), &g, &mut m2).unwrap();
    assert_eq!(m1, m2);
}

// ---------------------------------------------------------------------------
// Traffic and timing ordering
// ---------------------------------------------------------------------------

#[test]
fn ring_moves_strictly_fewer_bytes_per_worker_than_all_to_all_at_k16() {
    // The acceptance bar: K=16, same CompressorSpec — per-worker simulated
    // wire bytes strictly below all-to-all's, and faster on the α–β model.
    let k = 16;
    let n = 1 << 16;
    let spec = CompressorSpec::qsgd_4bit();
    let (_, a2a_bytes, _) = run_algo(&CollectiveSpec::AllToAll, spec.codec(), k, n, 1, 9);
    let (_, ring_bytes, _) = run_algo(&CollectiveSpec::ring(), spec.codec(), k, n, 1, 9);
    let (a2a_pw, ring_pw) = (a2a_bytes as f64 / k as f64, ring_bytes as f64 / k as f64);
    assert!(
        ring_pw < a2a_pw,
        "ring must move strictly fewer bytes/worker: ring {ring_pw} vs a2a {a2a_pw}"
    );
    // ~8× at K=16 (15·|msg| vs ~1.875·|msg|) — leave generous slack for
    // per-segment framing and recompressed-sum entropy
    assert!(ring_pw * 4.0 < a2a_pw, "ring {ring_pw} vs a2a {a2a_pw}");
    // (ring is latency-bound at this small message size, so the *time*
    // ordering is asserted on the traffic models with a large message in
    // `traffic_models_match_measured_shape`, and in the bench at real
    // model sizes — the bytes ordering is what this bar demands)
    // hierarchical sits between: below all-to-all as well
    let (_, hier_bytes, _) =
        run_algo(&CollectiveSpec::hierarchical(4), spec.codec(), k, n, 1, 9);
    assert!((hier_bytes as f64 / k as f64) < a2a_pw);
}

#[test]
fn traffic_models_match_measured_shape() {
    // bytes_per_worker (the epoch_sim accounting) must agree with the
    // measured exchange to first order: same ordering, right K-scaling.
    let k = 16;
    let msg = 1_000_000usize;
    let spec = CompressorSpec::qsgd_4bit();
    let a2a = AllToAll::new(spec.codec(), k, 0);
    let ring = RingAllreduce::new(spec.codec(), k, 0, true, false);
    let hier = Hierarchical::new(spec.codec(), k, 0, 4);
    let bpw_a2a = a2a.bytes_per_worker(k, msg);
    let bpw_ring = ring.bytes_per_worker(k, msg);
    let bpw_hier = hier.bytes_per_worker(k, msg);
    assert_eq!(bpw_a2a, 15.0 * msg as f64);
    assert!((bpw_ring - 2.0 * 15.0 / 16.0 * msg as f64).abs() < 1e-6);
    // hier:4 at K=16 lands exactly on the ring's 2(K−1)/K·|msg| average
    // (12 fan-ins + 12 fan-outs + a 4-leader ring, spread over 16 workers)
    assert!(bpw_ring <= bpw_hier && bpw_hier < bpw_a2a, "{bpw_ring} {bpw_hier} {bpw_a2a}");
    // model times follow the same ordering on the broadcast-hostile link
    let nn = net(k);
    let t_a2a = a2a.model_time(&nn, msg).secs();
    let t_ring = ring.model_time(&nn, msg).secs();
    assert!(t_ring < t_a2a, "{t_ring} vs {t_a2a}");
    // single worker: everything is free
    assert_eq!(ring.bytes_per_worker(1, msg), 0.0);
    assert_eq!(a2a.model_time(&net(1), msg).secs(), 0.0);
}

#[test]
fn hop_stats_cover_the_exchange() {
    let k = 8;
    let n = 512 * 8;
    let spec = CompressorSpec::qsgd_4bit();
    let g = grads(k, n, 31);
    let mut mean = Vec::new();

    let mut ring = RingAllreduce::new(spec.codec(), k, 3, true, false);
    let x = ring.exchange(&net(k), &g, &mut mean).unwrap();
    let hops = ring.hop_stats();
    assert_eq!(hops.len(), x.hops);
    assert_eq!(hops.len(), 2 * (k - 1));
    assert!(hops.iter().take(k - 1).all(|h| h.phase == "reduce-scatter"));
    assert!(hops.iter().skip(k - 1).all(|h| h.phase == "allgather"));
    let t: f64 = hops.iter().map(|h| h.time.secs()).sum();
    assert!((t - x.time.secs()).abs() < 1e-12);
    assert!(hops.iter().all(|h| h.bytes > 0));
    assert_eq!(x.recompressions as usize, k * (k - 1));

    let mut hier = Hierarchical::new(spec.codec(), k, 3, 4);
    let xh = hier.exchange(&net(k), &g, &mut mean).unwrap();
    let hh = hier.hop_stats();
    assert_eq!(hh.len(), xh.hops);
    assert_eq!(hh.first().map(|h| h.phase), Some("fan-in"));
    assert_eq!(hh.last().map(|h| h.phase), Some("fan-out"));
    let th: f64 = hh.iter().map(|h| h.time.secs()).sum();
    assert!((th - xh.time.secs()).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Error feedback
// ---------------------------------------------------------------------------

#[test]
fn error_feedback_compensates_recompression_over_steps() {
    // Repeatedly exchanging the *same* gradients: with an ECQ-style
    // residual the time-averaged aggregate converges toward the exact mean
    // (the carried error is re-injected and eventually quantized away);
    // without it each step pays the full independent recompression noise.
    let k = 8;
    let n = 512 * 8;
    let steps = 40;
    let g = grads(k, n, 77);
    let exact: Vec<f32> = {
        let mut m = vec![0.0f32; n];
        for gw in &g {
            for (a, &x) in m.iter_mut().zip(gw) {
                *a += x / k as f32;
            }
        }
        m
    };
    let time_avg_err = |ef: bool| -> f64 {
        let spec = CompressorSpec::qsgd_4bit();
        let mut algo = RingAllreduce::new(spec.codec(), k, 13, true, ef);
        let mut mean = Vec::new();
        let mut avg = vec![0.0f64; n];
        for _ in 0..steps {
            algo.exchange(&net(k), &g, &mut mean).unwrap();
            for (a, &m) in avg.iter_mut().zip(&mean) {
                *a += m as f64 / steps as f64;
            }
        }
        avg.iter().zip(&exact).map(|(a, &e)| (a - e as f64).powi(2)).sum::<f64>().sqrt()
    };
    let with_ef = time_avg_err(true);
    let without = time_avg_err(false);
    // EF's telescoping residual decays the time-averaged error ~1/T while
    // independent recompression noise only averages down ~1/√T; allow a
    // small margin so the assertion tests the mechanism, not one seed.
    assert!(
        with_ef <= without * 1.05,
        "error feedback should not hurt the time-averaged aggregate: {with_ef} vs {without}"
    );
    // and the recompression error is actually being tracked
    let codec = CompressorSpec::qsgd_4bit().codec();
    let (_, _, x) = run_algo(&CollectiveSpec::ring(), codec, k, n, 1, 13);
    assert!(x.recompress_err_sq > 0.0);
}

// ---------------------------------------------------------------------------
// Degenerate shapes
// ---------------------------------------------------------------------------

#[test]
fn degenerate_worker_counts_and_sizes() {
    for spec in all_collectives() {
        // single worker: the collective degrades to encode→decode of the
        // own gradient, no wire traffic
        let c = CompressorSpec::qsgd_4bit();
        let (m, bytes, x) = run_algo(&spec, c.codec(), 1, 700, 2, 5);
        assert_eq!(m.len(), 700, "{}", spec.label());
        assert_eq!(bytes, 0, "{}: single worker must not touch the wire", spec.label());
        assert_eq!(x.time.secs(), 0.0, "{}", spec.label());
        // k=2 minimal ring / one-group hierarchy
        let (m2, _, _) = run_algo(&spec, c.codec(), 2, 700, 2, 5);
        assert_eq!(m2.len(), 700, "{}", spec.label());
        assert!(m2.iter().all(|v| v.is_finite()), "{}", spec.label());
        // n smaller than one bucket
        let (m3, _, _) = run_algo(&spec, c.codec(), 4, 100, 1, 5);
        assert_eq!(m3.len(), 100, "{}", spec.label());
    }
}

#[test]
fn fixed_layout_codecs_are_rejected_by_segmented_collectives() {
    // 1BitSGD's session pins one gradient layout at first use, so the
    // segmented collectives must refuse with a clear error instead of
    // tripping the session's layout assert mid-hop.
    let k = 4;
    let g = grads(k, 256, 1);
    let mut mean = Vec::new();
    let codec = CompressorSpec::OneBit { column: 32 }.codec();
    let mut ring = RingAllreduce::new(codec, k, 1, true, false);
    let err = ring.exchange(&net(k), &g, &mut mean).unwrap_err();
    assert!(err.to_string().contains("all-to-all"), "{err:#}");
    let mut hier = Hierarchical::new(CompressorSpec::OneBit { column: 32 }.codec(), k, 1, 2);
    assert!(hier.exchange(&net(k), &g, &mut mean).is_err());
    // ...while the all-to-all arm carries 1BitSGD fine
    let mut a2a = AllToAll::new(CompressorSpec::OneBit { column: 32 }.codec(), k, 1);
    assert!(a2a.exchange(&net(k), &g, &mut mean).is_ok());
    // TernGrad sessions are stateless per call — the segmented path works
    let tern = CompressorSpec::TernGrad { bucket: 32 }.codec();
    let mut tring = RingAllreduce::new(tern, k, 1, true, false);
    let x = tring.exchange(&net(k), &g, &mut mean).unwrap();
    assert!(x.recompressions > 0);
}

#[test]
fn fp32_collectives_recover_the_exact_mean() {
    // With the identity codec every algorithm must reproduce the exact
    // arithmetic mean (ring hops add in a different order, so compare with
    // a tolerance rather than bitwise).
    let k = 4;
    let n = 1000;
    let g = grads(k, n, 55);
    let mut exact = vec![0.0f32; n];
    for gw in &g {
        for (a, &x) in exact.iter_mut().zip(gw) {
            *a += x / k as f32;
        }
    }
    for spec in all_collectives() {
        let (m, _, x) = run_algo(&spec, CompressorSpec::Fp32.codec(), k, n, 1, 5);
        for (a, b) in m.iter().zip(&exact) {
            assert!((a - b).abs() <= 1e-5, "{}: {a} vs {b}", spec.label());
        }
        // fp32 recompression is lossless: zero recompression error
        assert!(x.recompress_err_sq < 1e-12, "{}", spec.label());
    }
}

// ---------------------------------------------------------------------------
// Zero steady-state allocations in the hop re-encode path
// ---------------------------------------------------------------------------

#[test]
fn ring_hop_reencode_path_is_allocation_free_in_steady_state() {
    // Uniform-grid QSGD (v1 frames: no in-band tables on decode). After a
    // warmup exchange has grown all scratch, a full ring exchange — decode,
    // accumulate, per-hop re-encode, final decode — must not touch the
    // heap, with and without the error-feedback residual.
    let k = 8;
    let n = 2 * 512 * 8;
    let g = grads(k, n, 17);
    for ef in [false, true] {
        let spec = CompressorSpec::qsgd_4bit();
        let mut algo = RingAllreduce::new(spec.codec(), k, 23, true, ef);
        algo.prepare(n);
        let mut mean = Vec::new();
        for _ in 0..2 {
            algo.exchange(&net(k), &g, &mut mean).unwrap();
        }
        let before = local_allocs();
        algo.exchange(&net(k), &g, &mut mean).unwrap();
        let after = local_allocs();
        assert_eq!(
            after - before,
            0,
            "ring (ef={ef}) hop re-encode path allocated in steady state"
        );
    }
}
