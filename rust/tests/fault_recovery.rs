//! Trainer-recovery integration goldens — real loopback meshes in threads.
//!
//! These drive the socket recovery protocol end to end without child
//! processes: K threads each connect a real [`Mesh`] over loopback TCP,
//! wrap it in a [`SocketExchange`] with recovery enabled, and face seeded
//! outbound fault injection. The acceptance bar is bit parity:
//!
//! * a **corruption-recovered** exchange must produce exactly the bytes a
//!   fault-free run produces (the resend carries the original frame);
//! * a **dead-worker** exchange must produce exactly the bytes the
//!   in-process renormalized golden (`build_with_scenario` + `drop:R@S`)
//!   produces on every survivor;
//! * `ring:ef` **residuals survive** a recovered step — later steps stay
//!   bit-identical to the fault-free trajectory.
//!
//! The `FaultInjector::damage` constant XORs a frame's first byte with
//! 0xA5 — exactly `FRAME_MAGIC` — so a damaged codec frame always fails
//! decode validation instead of sometimes parsing into garbage.

use std::time::Duration;

use qsgd::collectives;
use qsgd::config::{CollectiveSpec, ScenarioSpec};
use qsgd::coordinator::CompressorSpec;
use qsgd::metrics::FaultStats;
use qsgd::simnet::{Link, SimNet, Topology};
use qsgd::transport::{
    DistStats, Endpoint, FaultInjector, Mesh, MeshConfig, RecoveryOptions, SocketExchange,
};
use qsgd::util::rng::{self, Xoshiro256};

const WORLD: usize = 4;
/// Ragged tail (not a multiple of bucket·K) exercises short final segments.
const N: usize = 2 * 512 * 4 + 29;
const SEED: u64 = 7;
const GSEED: u64 = 99;

/// A free TCP port on loopback: bind :0, read the address, release it.
fn free_tcp_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("binding probe socket");
    l.local_addr().expect("probe addr").to_string()
}

/// Run `f(rank, mesh)` on `world` threads over one real loopback mesh.
fn run_world<T: Send>(world: usize, io_ms: u64, f: impl Fn(usize, Mesh) -> T + Sync) -> Vec<T> {
    let base = Endpoint::Tcp(free_tcp_addr());
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..world)
            .map(|r| {
                let base = base.clone();
                let f = &f;
                s.spawn(move || {
                    let mesh = Mesh::connect(
                        &base,
                        &MeshConfig {
                            rank: r,
                            world,
                            io_timeout: Duration::from_millis(io_ms),
                            connect_timeout: Duration::from_secs(30),
                        },
                    )
                    .unwrap_or_else(|e| panic!("rank {r} mesh: {e:#}"));
                    f(r, mesh)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
    })
}

fn grad_for(rank: usize) -> Vec<f32> {
    rng::normal_vec(&mut Xoshiro256::stream(GSEED, rank as u64), N)
}

/// In-process golden: the same collective (scenario-aware) at the same
/// seeds — the bits every socket-side mean must match exactly.
fn golden_mean(spec: &CollectiveSpec, scenario: &ScenarioSpec, steps: usize) -> Vec<f32> {
    let grads: Vec<Vec<f32>> = (0..WORLD).map(grad_for).collect();
    let net = SimNet::new(WORLD, Link::new(1e9, 1e-6), Topology::P2pBroadcast);
    let codec = CompressorSpec::qsgd_4bit().codec();
    let mut algo =
        collectives::build_with_scenario(spec, scenario, codec, WORLD, SEED).expect("golden algo");
    algo.prepare(N);
    let mut mean = Vec::new();
    for _ in 0..steps {
        algo.exchange(&net, &grads, &mut mean).expect("golden exchange");
    }
    mean
}

fn assert_mean_matches(tag: &str, rank: usize, got: &[f32], want: &[f32]) {
    assert!(want.iter().any(|&x| x != 0.0), "{tag}: golden mean is all zeros");
    assert_eq!(got.len(), want.len(), "{tag}: rank {rank} mean length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{tag}: rank {rank} diverges from the golden at coord {i}: \
             {a} ({:#010x}) vs {b} ({:#010x})",
            a.to_bits(),
            b.to_bits()
        );
    }
}

fn sum_faults(stats: &[&DistStats]) -> FaultStats {
    let mut f = FaultStats::default();
    for s in stats {
        f.add(&s.faults);
    }
    f
}

#[test]
fn corrupt_frames_are_rerequested_from_live_peers_bit_identically() {
    let spec = CollectiveSpec::AllToAll;
    let steps = 2;
    // Recovery resends carry the original bytes, so the golden is simply
    // the fault-free run.
    let want = golden_mean(&spec, &ScenarioSpec::None, steps);
    let results = run_world(WORLD, 10_000, |rank, mut mesh| {
        if rank == 1 {
            // Rank 1's first two outbound data frames arrive undecodable
            // (0xA5 XOR kills the frame magic); everything after is clean.
            mesh.set_fault_injector(
                FaultInjector::new(0xFA17).with_corruption(1.0).with_max_faults(2),
            );
        }
        let mut ex =
            SocketExchange::new(&spec, CompressorSpec::qsgd_4bit().codec(), mesh, SEED)
                .expect("exchange")
                .with_recovery(RecoveryOptions::on())
                .expect("recovery");
        let grad = grad_for(rank);
        let mut mean = Vec::new();
        let mut total = DistStats::default();
        for _ in 0..steps {
            let s =
                ex.exchange(&grad, &mut mean).unwrap_or_else(|e| panic!("rank {rank}: {e:#}"));
            total.add(&s);
        }
        (mean, total)
    });
    for (rank, (mean, _)) in results.iter().enumerate() {
        assert_mean_matches("corrupt-rerequest", rank, mean, &want);
    }
    let f = sum_faults(&results.iter().map(|(_, s)| s).collect::<Vec<_>>());
    assert_eq!(f.corrupt_frames, 2, "both damaged frames must be detected");
    assert_eq!(f.rerequests, 2, "both damaged frames must be re-requested");
    assert_eq!(f.resends_served, 2, "rank 1 must serve both resends");
    assert_eq!(f.dead_workers, 0);
    assert_eq!(f.renormalized_steps, 0, "all workers contributed — no renormalization");
}

#[test]
fn dead_worker_skip_is_bit_deterministic_across_survivors() {
    let spec = CollectiveSpec::AllToAll;
    let steps = 2;
    // Rank 3 dies before ever sending, so both steps renormalize over
    // {0,1,2} — exactly the in-process drop:3@0 schedule.
    let want = golden_mean(&spec, &ScenarioSpec::Drop { rank: 3, step: 0 }, steps);
    let results = run_world(WORLD, 4_000, |rank, mesh| {
        if rank == 3 {
            // Dies at the top of step 0: full mesh joined, nothing sent.
            drop(mesh);
            return None;
        }
        let mut ex =
            SocketExchange::new(&spec, CompressorSpec::qsgd_4bit().codec(), mesh, SEED)
                .expect("exchange")
                .with_recovery(RecoveryOptions::on())
                .expect("recovery");
        let grad = grad_for(rank);
        let mut mean = Vec::new();
        let mut total = DistStats::default();
        for _ in 0..steps {
            let s =
                ex.exchange(&grad, &mut mean).unwrap_or_else(|e| panic!("rank {rank}: {e:#}"));
            total.add(&s);
        }
        Some((mean, total))
    });
    let survivors: Vec<&(Vec<f32>, DistStats)> =
        results.iter().filter_map(|r| r.as_ref()).collect();
    assert_eq!(survivors.len(), WORLD - 1);
    for (i, (mean, stats)) in survivors.iter().enumerate() {
        assert_mean_matches("dead-worker-skip", i, mean, &want);
        assert_eq!(stats.faults.dead_workers, 1, "death is counted once, in step 0");
        assert_eq!(stats.faults.renormalized_steps, steps as u64);
        assert_eq!(stats.faults.corrupt_frames, 0);
    }
    // Bit determinism across survivors is implied by each matching the
    // golden, but assert it directly for a sharper failure message.
    for w in &survivors[1..] {
        assert_eq!(w.0, survivors[0].0, "survivors must agree bit for bit");
    }
}

#[test]
fn ring_ef_residuals_survive_a_recovered_step() {
    let spec = CollectiveSpec::ring_ef();
    let steps = 3;
    // The repaired hop carries the exact bytes the fault destroyed, so the
    // whole faulted run — residual evolution included — is bit-identical
    // to the fault-free golden.
    let want = golden_mean(&spec, &ScenarioSpec::None, steps);
    let results = run_world(WORLD, 10_000, |rank, mut mesh| {
        if rank == 2 {
            // One corrupted reduce-scatter hop frame in step 0.
            mesh.set_fault_injector(
                FaultInjector::new(0xFA17).with_corruption(1.0).with_max_faults(1),
            );
        }
        let mut ex =
            SocketExchange::new(&spec, CompressorSpec::qsgd_4bit().codec(), mesh, SEED)
                .expect("exchange")
                .with_recovery(RecoveryOptions::on())
                .expect("recovery");
        let grad = grad_for(rank);
        let mut mean = Vec::new();
        let mut total = DistStats::default();
        for _ in 0..steps {
            let s =
                ex.exchange(&grad, &mut mean).unwrap_or_else(|e| panic!("rank {rank}: {e:#}"));
            total.add(&s);
        }
        (mean, total)
    });
    for (rank, (mean, _)) in results.iter().enumerate() {
        assert_mean_matches("ring-ef-recovered", rank, mean, &want);
    }
    let f = sum_faults(&results.iter().map(|(_, s)| s).collect::<Vec<_>>());
    assert_eq!(f.corrupt_frames, 1, "exactly one damaged hop frame");
    assert_eq!(f.rerequests, 1);
    assert_eq!(f.resends_served, 1, "rank 2 must serve the resend");
    assert_eq!(f.dead_workers, 0);
}

#[test]
fn recovery_refuses_backends_that_fail_clean() {
    // ring:raw and hier have no bounded recovery path; with_recovery must
    // refuse up front instead of deadlocking mid-hop.
    let results = run_world(2, 4_000, |_rank, mesh| {
        let ex = SocketExchange::new(
            &CollectiveSpec::parse("ring:raw").unwrap(),
            CompressorSpec::qsgd_4bit().codec(),
            mesh,
            SEED,
        )
        .expect("exchange");
        ex.with_recovery(RecoveryOptions::on()).err().map(|e| e.to_string())
    });
    for err in results {
        let err = err.expect("ring:raw must refuse recovery");
        assert!(err.contains("fails clean"), "{err}");
    }
}
