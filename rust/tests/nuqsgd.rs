//! NUQSGD / non-uniform level grids through the fused pipeline.
//!
//! The validation template from PR 1, generalized per grid:
//!
//! * fused and two-phase compressors emit **bit-identical** wire bytes for
//!   every grid family (uniform, exponential, custom), across regimes,
//!   norms, bucket sizes and adversarial inputs;
//! * uniform frames remain **byte-identical to PR 1's v1 wire format**
//!   (pinned by golden frames computed independently of the encoder);
//! * quantization onto any grid is statistically unbiased and its empirical
//!   variance respects the grid's analytic envelope (NUQSGD-style bound for
//!   the exponential grid);
//! * v2 frames (in-band grid tag) round-trip through `decode`, `decode_add`
//!   and the session-based `Codec` API.

mod common;

use qsgd::coding::gradient::{self, Regime};
use qsgd::coding::{QsgdCodec, TwoPhaseQsgd};
use qsgd::coordinator::CompressorSpec;
use qsgd::prop_assert;
use qsgd::quant::{
    stochastic, Codec, EncodeSession, LevelGrid, Norm, QuantBucket, QuantizedGradient,
};
use qsgd::util::check::forall;
use qsgd::util::rng::{self, Xoshiro256};

#[test]
fn prop_fused_bit_identical_to_two_phase_for_every_grid() {
    forall("grid-fused-vs-two-phase", 160, 4000, |g| {
        let (n, bucket) = common::gen_dims(g);
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let norm = common::gen_norm(g);
        let regime = common::gen_regime(g);
        let seed = common::gen_seed(g);
        let mut oracle = TwoPhaseQsgd::with_grid(grid.clone(), bucket, norm, regime)
            .session(Xoshiro256::from_u64(seed));
        let mut fused = QsgdCodec::with_grid(grid.clone(), bucket, norm, regime)
            .session(Xoshiro256::from_u64(seed));
        let a = oracle.compress(&v);
        let b = fused.compress(&v);
        prop_assert!(
            a == b,
            "wire bytes differ: n={n} bucket={bucket} {norm:?} {regime:?} grid={}",
            grid.label()
        );
        // the frame decodes, reports the right length, and carries the grid
        let q = gradient::decode(&a).map_err(|e| e.to_string())?;
        prop_assert!(q.n == n, "decoded length {} != {n}", q.n);
        prop_assert!(q.grid == grid, "decoded grid mismatch");
        // decode_add agrees with decode-then-dequantize for every grid
        let mut acc1 = vec![0.25f32; n];
        gradient::decode_add(&a, 0.5, &mut acc1).map_err(|e| e.to_string())?;
        let mut acc2 = vec![0.25f32; n];
        q.dequantize_add(0.5, &mut acc2);
        for i in 0..n {
            prop_assert!(
                (acc1[i] - acc2[i]).abs() <= 1e-6 * acc2[i].abs().max(1.0)
                    || (acc1[i].is_nan() && acc2[i].is_nan()),
                "decode_add diverges at {i}: {} vs {}",
                acc1[i],
                acc2[i]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_grid_matches_legacy_qsgd_oracle() {
    // The grid machinery must be invisible for uniform grids: QsgdCodec over
    // LevelGrid::uniform(s) == the PR 1 uniform QSGD encoder, byte for byte
    // (the two-phase oracle quantizes via the legacy arithmetic).
    forall("uniform-grid-legacy", 80, 3000, |g| {
        let (n, bucket) = common::gen_dims(g);
        let v = common::gen_vec(g, n);
        let s = [1u32, 4, 15, 255][g.usize_in(0, 3)];
        let norm = common::gen_norm(g);
        let regime = common::gen_regime(g);
        let seed = common::gen_seed(g);
        let mut legacy =
            TwoPhaseQsgd::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(seed));
        let mut grid = QsgdCodec::with_grid(LevelGrid::uniform(s), bucket, norm, regime)
            .session(Xoshiro256::from_u64(seed));
        let a = legacy.compress(&v);
        let b = grid.compress(&v);
        prop_assert!(a == b, "uniform grid diverged from legacy: n={n} s={s}");
        Ok(())
    });
}

#[test]
fn prop_spec_built_nuqsgd_matches_two_phase_oracle() {
    // Through the coordinator's factory (the path the trainers take).
    forall("spec-nuqsgd-oracle", 60, 3000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let spec = [
            CompressorSpec::nuqsgd_4bit(),
            CompressorSpec::Nuqsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None },
            CompressorSpec::Nuqsgd { bits: 8, bucket: 512, norm: Norm::L2, regime: None },
        ][g.usize_in(0, 2)]
        .clone();
        let seed = common::gen_seed(g);
        let fused_codec = spec.codec();
        let oracle_codec = spec.codec_two_phase();
        let a = fused_codec.session(Xoshiro256::from_u64(seed)).compress(&v);
        let b = oracle_codec.session(Xoshiro256::from_u64(seed)).compress(&v);
        prop_assert!(a == b, "{}: codec() and codec_two_phase() bytes differ", spec.label());
        let mut acc_a = vec![0.5f32; n];
        let mut acc_b = vec![0.5f32; n];
        fused_codec.decode_add(&a, 0.25, &mut acc_a).map_err(|e| e.to_string())?;
        oracle_codec.decode_add(&b, 0.25, &mut acc_b).map_err(|e| e.to_string())?;
        prop_assert!(acc_a == acc_b, "decode-accumulate differs");
        Ok(())
    });
}

#[test]
fn prop_grid_quantizer_invariants() {
    forall("grid-quantizer", 120, 2000, |g| {
        let n = g.usize_in(1, g.size.max(1));
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let bucket = 1 + g.usize_in(0, n);
        let norm = common::gen_norm(g);
        let q = stochastic::quantize_grid(&v, &grid, bucket, norm, g.rng);
        prop_assert!(q.n == n, "length");
        prop_assert!(q.s == grid.s(), "s mismatch");
        let s = grid.s();
        let d = q.dequantize();
        let mut off = 0;
        for b in &q.buckets {
            prop_assert!(
                b.levels.iter().all(|&l| l.unsigned_abs() <= s),
                "level exceeds s"
            );
            for i in 0..b.levels.len() {
                let (x, y) = (v[off + i], d[off + i]);
                // reconstruction stays inside [0, scale] in magnitude and
                // preserves sign
                if b.scale > 0.0 && y != 0.0 {
                    prop_assert!(y.abs() <= b.scale * 1.0001, "|recon| beyond scale");
                    if x != 0.0 && !x.is_nan() {
                        prop_assert!((y > 0.0) == (x > 0.0), "sign flipped at {}", off + i);
                    }
                }
            }
            off += b.levels.len();
        }
        Ok(())
    });
}

#[test]
fn grid_quantization_is_statistically_unbiased() {
    // E[Q(v)] = v for both the uniform and the exponential grid (and the
    // same stochastic-rounding argument covers custom grids).
    let mut data_rng = Xoshiro256::from_u64(31);
    let v: Vec<f32> = (0..48).map(|_| rng::normal_f32(&mut data_rng)).collect();
    let trials = 6000usize;
    for (grid, norm) in [
        (LevelGrid::uniform(3), Norm::L2),
        (LevelGrid::exponential(4), Norm::L2),
        (LevelGrid::exponential(4), Norm::Max),
        (LevelGrid::custom(vec![0.17, 0.42, 1.0]).unwrap(), Norm::Max),
    ] {
        let mut r = Xoshiro256::stream(7, grid.s() as u64);
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let q = stochastic::quantize_grid(&v, &grid, v.len(), norm, &mut r);
            for (a, x) in acc.iter_mut().zip(q.dequantize()) {
                *a += x as f64;
            }
        }
        let scale = norm.scale(&v) as f64;
        // worst-case per-coordinate stderr is (gap/2)/√trials with gap ≤
        // scale; allow a generous 6σ
        let tol = 6.0 * 0.5 * scale / (trials as f64).sqrt();
        for (i, (&a, &x)) in acc.iter().zip(&v).enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - x as f64).abs() < tol,
                "{} coordinate {i} biased: mean {mean} vs {x} (tol {tol})",
                grid.label()
            );
        }
    }
}

#[test]
fn grid_variance_respects_analytic_envelope() {
    // Empirical E‖Q(v) − v‖² against each grid's rigorous bound for 2-norm
    // buckets (Lemma 3.1(ii) for uniform; the ε²/4 + ℓ₁√d envelope — the
    // NUQSGD-style bound — for non-uniform grids). Also cross-check against
    // the exact sum of per-coordinate rounding variances.
    let n = 256;
    let mut data_rng = Xoshiro256::from_u64(33);
    let v: Vec<f32> = (0..n).map(|_| rng::normal_f32(&mut data_rng)).collect();
    let vnorm = Norm::L2.scale(&v) as f64;
    let vnorm2 = vnorm * vnorm;
    for grid in [
        LevelGrid::uniform(4),
        LevelGrid::exponential(4),
        LevelGrid::exponential(8),
        LevelGrid::custom(vec![0.05, 0.3, 0.6, 1.0]).unwrap(),
    ] {
        // exact expected variance: Σ_i F² · var(a_i) with a_i = |v_i|/F
        let exact: f64 = v
            .iter()
            .map(|&x| vnorm2 * grid.rounding_variance((x.abs() as f64 / vnorm) as f32))
            .sum();
        let bound = grid.variance_bound(n) * vnorm2;
        assert!(
            exact <= bound,
            "{}: exact {exact} beats bound {bound}?",
            grid.label()
        );
        let trials = 600;
        let mut r = Xoshiro256::stream(11, grid.s() as u64);
        let mut tot = 0.0f64;
        for _ in 0..trials {
            let q = stochastic::quantize_grid(&v, &grid, n, Norm::L2, &mut r);
            let d = q.dequantize();
            tot += v
                .iter()
                .zip(&d)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        let emp = tot / trials as f64;
        assert!(
            emp <= exact * 1.15 + 1e-12,
            "{}: empirical {emp} vs exact {exact}",
            grid.label()
        );
        assert!(emp <= bound * 1.05, "{}: empirical {emp} vs bound {bound}", grid.label());
    }
}

#[test]
fn exponential_grid_refines_small_coordinates() {
    // The NUQSGD rationale: for normalized gradients most coordinates are
    // far below the bucket scale, where the exponential grid's gaps (and so
    // its rounding variance) are much finer than the uniform grid's at the
    // same level count.
    let uni = LevelGrid::uniform(8);
    let exp = LevelGrid::exponential(8);
    for a in [0.002f32, 0.004, 0.01] {
        assert!(
            exp.rounding_variance(a) < uni.rounding_variance(a),
            "a={a}: exp {} vs uniform {}",
            exp.rounding_variance(a),
            uni.rounding_variance(a)
        );
    }
}

// ---------------------------------------------------------------------------
// Wire-format goldens: frames assembled from known levels (no RNG), with the
// expected bytes computed independently of the encoder. These pin the
// formats: v1 (uniform, PR 1's exact layout) and v2 (in-band grid tag).
// ---------------------------------------------------------------------------

fn frame(
    grid: LevelGrid,
    bucket_size: usize,
    norm: Norm,
    n: usize,
    buckets: Vec<QuantBucket>,
) -> QuantizedGradient {
    QuantizedGradient { s: grid.s(), grid, bucket_size, norm, n, buckets }
}

#[test]
fn golden_v1_uniform_frames_stay_byte_identical_to_pr1() {
    // s=1, n=2, bucket=2, max-norm, levels [0, -1], scale 1.0.
    let q = frame(
        LevelGrid::uniform(1),
        2,
        Norm::Max,
        2,
        vec![QuantBucket { scale: 1.0, levels: vec![0, -1] }],
    );
    // magic | v1 | regime | norm | Elias(1) | Elias'(2) | Elias(2) | bucket
    assert_eq!(gradient::encode(&q, Regime::Dense), hex("a515a1fc00000240"));
    assert_eq!(gradient::encode(&q, Regime::Sparse), hex("a51da1fc00000490"));
    // and they decode back to the same object
    assert_eq!(gradient::decode(&hex("a515a1fc00000240")).unwrap(), q);
}

#[test]
fn golden_v2_nuqsgd_frame() {
    // exponential grid s=2 ({0, 1/2, 1}), n=3, bucket=3, max-norm, dense,
    // levels [1, 0, -2], scale 2.0. Grid tag Elias(1) after the v1 fields.
    let q = frame(
        LevelGrid::exponential(2),
        3,
        Norm::Max,
        3,
        vec![QuantBucket { scale: 2.0, levels: vec![1, 0, -2] }],
    );
    let bytes = gradient::encode(&q, Regime::Dense);
    assert_eq!(bytes, hex("a526518800000010d0"));
    assert_eq!(gradient::decode(&bytes).unwrap(), q);
    // dequantizes through the grid's point table: ±scale·{1/2, 1}
    assert_eq!(gradient::decode(&bytes).unwrap().dequantize(), vec![1.0, 0.0, -2.0]);
}

#[test]
fn golden_v2_custom_grid_frame() {
    // custom grid {0.25, 1.0} (s=2), n=2, bucket=2, L2 norm, sparse,
    // levels [2, 0], scale 4.0. Grid tag Elias(2), then the two points.
    let q = frame(
        LevelGrid::custom(vec![0.25, 1.0]).unwrap(),
        2,
        Norm::L2,
        2,
        vec![QuantBucket { scale: 4.0, levels: vec![2, 0] }],
    );
    let bytes = gradient::encode(&q, Regime::Sparse);
    assert_eq!(bytes, hex("a52a690fa000000fe00000102000002100"));
    assert_eq!(gradient::decode(&bytes).unwrap(), q);
    assert_eq!(gradient::decode(&bytes).unwrap().dequantize(), vec![4.0, 0.0]);
}

fn hex(s: &str) -> Vec<u8> {
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
        .collect()
}

// ---------------------------------------------------------------------------
// End-to-end trait plumbing
// ---------------------------------------------------------------------------

#[test]
fn nuqsgd_codec_roundtrips_and_reports_reasonable_size() {
    let mut data_rng = Xoshiro256::from_u64(40);
    let v: Vec<f32> = (0..3000).map(|_| rng::normal_f32(&mut data_rng)).collect();
    let c = QsgdCodec::nuqsgd_with_bits(4, 512);
    let msg = c.session(Xoshiro256::from_u64(41)).compress(&v);
    let back = c.decode(&msg, v.len()).unwrap();
    assert_eq!(back.len(), v.len());
    // reconstruction is bounded by the bucket scale, per coordinate
    for (cg, cb) in v.chunks(512).zip(back.chunks(512)) {
        let scale = cg.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (g, b) in cg.iter().zip(cb) {
            assert!((g - b).abs() <= scale + 1e-6);
            // one-sided check: rounding moves at most one grid gap, and the
            // largest gap of the exponential grid is scale/2
            assert!((g - b).abs() <= scale / 2.0 + 1e-6);
        }
    }
    // 4-bit-budget NUQSGD stays well below fp32 on the wire
    assert!(msg.len() * 3 < v.len() * 4, "msg {} bytes", msg.len());
    // wrong expected length is rejected
    assert!(c.decode(&msg, v.len() + 1).is_err());
}

#[test]
fn fused_nuqsgd_scratch_reuse_stays_bit_identical_across_varied_lengths() {
    let mut fused = QsgdCodec::nuqsgd_with_bits(4, 512).session(Xoshiro256::from_u64(42));
    let mut oracle = TwoPhaseQsgd::nuqsgd_with_bits(4, 512).session(Xoshiro256::from_u64(42));
    let mut data_rng = Xoshiro256::from_u64(1);
    for (round, base) in [0usize, 1, 5, 511, 512, 513, 6000, 100, 512, 3].iter().enumerate() {
        let n = base + round;
        let v: Vec<f32> = (0..n).map(|_| rng::normal_f32(&mut data_rng)).collect();
        let a = oracle.compress(&v);
        let b = fused.compress(&v);
        assert_eq!(a, b, "round {round} (n={n})");
    }
}
