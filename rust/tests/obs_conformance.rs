//! Conformance suite for the observability layer (`qsgd::obs`):
//!
//! * the log-bucketed [`Histogram`] tracks an exact sorted-sample
//!   nearest-rank oracle within its advertised `1/64` relative error bound
//!   on adversarial distributions (heavy-tailed, bimodal, constant, tiny,
//!   octave-spanning) — these are the only exact-quantile computations left
//!   in the tree, kept here as test oracles;
//! * [`MetricSet::merge`] is associative and commutative row-wise
//!   (counters and gauges exactly; histogram quantiles exactly, means up to
//!   float-addition reordering);
//! * **zero steady-state allocation**: with tracing disabled (the default)
//!   a span site is one atomic load; with tracing enabled at the default
//!   sampling rate, recording after the first-touch ring allocation is
//!   alloc-free; flight-recorder crumbs are alloc-free after the ring's
//!   first touch. Proven with a counting global allocator using a
//!   thread-local counter, so concurrently running tests don't pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use qsgd::obs::flight;
use qsgd::obs::trace::{Site, SpanGuard};
use qsgd::obs::{labeled, Histogram, MetricSet, MetricValue};

// ---------------------------------------------------------------------------
// Thread-local counting allocator
// ---------------------------------------------------------------------------

struct CountingAlloc;

std::thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocations made by *this* thread so far.
fn local_allocs() -> u64 {
    LOCAL_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

/// Deterministic uniform-[0,1) stream (splitmix-style LCG), so the
/// adversarial distributions below are reproducible without a seed file.
fn lcg(state: &mut u64) -> f64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*state >> 11) as f64) / (1u64 << 53) as f64
}

/// Exact nearest-rank quantile over an ascending-sorted sample vector —
/// the oracle the bounded-memory histogram is checked against.
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

// ---------------------------------------------------------------------------
// Histogram vs exact oracle
// ---------------------------------------------------------------------------

#[test]
fn histogram_quantiles_track_exact_oracle_on_adversarial_distributions() {
    let mut s = 0xD1CE_u64;
    // Pareto(α=1): heavy tail spanning many octaves.
    let heavy: Vec<f64> = (0..2000).map(|_| 1.0 / (1.0 - lcg(&mut s))).collect();
    // Two clusters six decades apart — quantiles must jump the gap cleanly.
    let bimodal: Vec<f64> = (0..1000)
        .map(|i| if i % 2 == 0 { 1e-3 * (1.0 + lcg(&mut s)) } else { 1e3 * (1.0 + lcg(&mut s)) })
        .collect();
    // Tiny but in-domain values (domain floor is 2^-64 ≈ 5.4e-20).
    let tiny: Vec<f64> = (0..1000).map(|_| 1e-18 * (1.0 + lcg(&mut s))).collect();
    // One sample per octave across most of the domain.
    let octaves: Vec<f64> =
        (0..1200).map(|i| 2f64.powi((i % 120) - 60) * (1.0 + lcg(&mut s))).collect();
    let cases: Vec<(&str, Vec<f64>)> = vec![
        ("heavy_tail", heavy),
        ("bimodal", bimodal),
        // Degenerate: every quantile must be the constant (min==max clamp).
        ("constant", vec![42.0; 1000]),
        ("tiny", tiny),
        ("mixed_octaves", octaves),
    ];

    for (name, mut xs) in cases {
        let h = Histogram::from_samples(&xs);
        xs.sort_by(f64::total_cmp);
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let exact = exact_quantile(&xs, q);
            let got = h.quantile(q);
            let tol = exact / 64.0;
            assert!(
                (got - exact).abs() <= tol,
                "{name} q={q}: hist {got} vs exact {exact} (tol {tol})"
            );
        }
        assert_eq!(h.count(), xs.len() as u64, "{name}: count");
        assert_eq!(h.quantile(1.0), xs[xs.len() - 1], "{name}: q=1.0 clamps to max");
    }
}

#[test]
fn histogram_merge_agrees_with_recording_everything_into_one() {
    let mut s = 7_u64;
    let a: Vec<f64> = (0..500).map(|_| 1.0 / (1.0 - lcg(&mut s))).collect();
    let b: Vec<f64> = (0..700).map(|_| 1e-6 * (1.0 + lcg(&mut s))).collect();
    let mut merged = Histogram::from_samples(&a);
    merged.merge(&Histogram::from_samples(&b));
    let mut all = a.clone();
    all.extend_from_slice(&b);
    let whole = Histogram::from_samples(&all);
    assert_eq!(merged.count(), whole.count());
    for q in [0.1, 0.5, 0.9, 0.99] {
        assert_eq!(merged.quantile(q), whole.quantile(q), "q={q}");
    }
}

// ---------------------------------------------------------------------------
// MetricSet merge algebra
// ---------------------------------------------------------------------------

/// A set with counter, gauge, and histogram rows plus one seed-unique
/// labeled row, so merges exercise both shared and disjoint keys.
fn sample_set(seed: u64) -> MetricSet {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    let mut m = MetricSet::new();
    m.counter("wire.messages", (lcg(&mut s) * 1000.0) as u64);
    m.counter("faults.rerequests", (lcg(&mut s) * 10.0) as u64);
    m.counter(&labeled("ps.pushes", "shard", seed), 7);
    m.gauge("occupancy.peak", lcg(&mut s));
    m.gauge("queue.depth", lcg(&mut s) * 64.0);
    for _ in 0..300 {
        m.observe("ps.push_decode_ns", 1.0 / (1.0 - lcg(&mut s)));
        m.observe("wall.encode_s", 1e-3 * (1.0 + lcg(&mut s)));
    }
    m
}

/// Row-wise equivalence: counters and gauges exact, histogram quantiles
/// exact (integer bucket counts), means up to float-addition reordering.
fn assert_equiv(x: &MetricSet, y: &MetricSet) {
    assert_eq!(x.len(), y.len());
    for ((nx, vx), (ny, vy)) in x.rows().zip(y.rows()) {
        assert_eq!(nx, ny);
        match (vx, vy) {
            (MetricValue::Counter(a), MetricValue::Counter(b)) => assert_eq!(a, b, "{nx}"),
            (MetricValue::Gauge(a), MetricValue::Gauge(b)) => assert_eq!(a, b, "{nx}"),
            (MetricValue::Hist(a), MetricValue::Hist(b)) => {
                assert_eq!(a.count(), b.count(), "{nx}: count");
                for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
                    assert_eq!(a.quantile(q), b.quantile(q), "{nx}: q={q}");
                }
                let (ma, mb) = (a.mean(), b.mean());
                assert!((ma - mb).abs() <= 1e-9 * ma.abs(), "{nx}: mean {ma} vs {mb}");
            }
            (a, b) => panic!("{nx}: kind mismatch {a:?} vs {b:?}"),
        }
    }
}

#[test]
fn metric_set_merge_is_commutative() {
    let (a, b) = (sample_set(1), sample_set(2));
    let mut ab = a.clone();
    ab.merge(&b);
    let mut ba = b.clone();
    ba.merge(&a);
    assert_equiv(&ab, &ba);
    // The seed-unique rows from both operands survive the merge.
    assert!(matches!(ab.get("ps.pushes{shard=1}"), Some(MetricValue::Counter(7))));
    assert!(matches!(ab.get("ps.pushes{shard=2}"), Some(MetricValue::Counter(7))));
}

#[test]
fn metric_set_merge_is_associative() {
    let (a, b, c) = (sample_set(1), sample_set(2), sample_set(3));
    let mut ab_c = a.clone();
    ab_c.merge(&b);
    ab_c.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);
    assert_equiv(&ab_c, &a_bc);
}

// ---------------------------------------------------------------------------
// Zero steady-state allocation
// ---------------------------------------------------------------------------

// One sequential test: the tracer's enabled flag is process-global, so
// splitting these phases into parallel #[test]s would race on it.
#[test]
fn observability_is_allocation_free_in_steady_state() {
    static SITE: Site = Site::new("conf.steady");
    static CRUMB_SITE: Site = Site::new("conf.crumb");

    // Phase 1 — tracing disabled (the default): a span site costs one
    // relaxed atomic load and must never touch the heap.
    qsgd::obs::set_enabled(false);
    for _ in 0..8 {
        let _g = SpanGuard::enter(&SITE);
    }
    let before = local_allocs();
    for _ in 0..10_000 {
        let _g = SpanGuard::enter(&SITE);
    }
    assert_eq!(local_allocs() - before, 0, "disabled span path allocated");

    // Phase 2 — tracing enabled at the default sampling rate: the first
    // span on a thread allocates its ring (warmup below); after that,
    // begin/end recording is relaxed stores into pre-allocated slots.
    qsgd::obs::set_sample_every(1);
    qsgd::obs::set_enabled(true);
    for _ in 0..8 {
        let _g = SpanGuard::enter(&SITE);
    }
    let before = local_allocs();
    for _ in 0..10_000 {
        let _g = SpanGuard::enter(&SITE);
    }
    assert_eq!(local_allocs() - before, 0, "warm enabled span path allocated");
    qsgd::obs::set_enabled(false);

    // Phase 3 — flight-recorder crumbs after the ring's first touch.
    for i in 0..8u64 {
        flight::crumb(&CRUMB_SITE, i, 0, 0);
    }
    let before = local_allocs();
    for i in 0..10_000u64 {
        flight::crumb(&CRUMB_SITE, i, i, i);
    }
    assert_eq!(local_allocs() - before, 0, "crumb path allocated");
}
