//! SIMD-vs-scalar bit-identity for the level-assignment hot path.
//!
//! The vectorized `quantize_bucket_into`/`quantize_bucket_into_grid`
//! (8-lane chunks, branch-free sign select, exponent-extraction bracket for
//! the exponential grid) must produce the **exact** levels and scale of the
//! scalar oracles they replaced, for every grid family, over the shared
//! adversarial generators (±0, subnormals, huge/tiny magnitudes, all-zero
//! buckets) and every tail length — byte-level wire identity of the whole
//! stack rides on this (the fused pipeline streams these levels straight
//! into the Elias coder).

mod common;

use qsgd::prop_assert;
use qsgd::quant::{stochastic, LevelGrid, Norm};
use qsgd::util::check::forall;
use qsgd::util::rng::Xoshiro256;
use rand_core::RngCore;

/// Compare SIMD vs scalar on one bucket; scales are compared bitwise.
fn assert_bucket_identical(
    v: &[f32],
    words: &[u8],
    grid: &LevelGrid,
    norm: Norm,
) -> Result<(), String> {
    let mut simd = vec![0i32; v.len()];
    let mut scalar = vec![0i32; v.len()];
    let ss = stochastic::quantize_bucket_into_grid(v, words, grid, norm, &mut simd);
    let sc = stochastic::quantize_bucket_into_grid_scalar(v, words, grid, norm, &mut scalar);
    prop_assert!(
        ss.to_bits() == sc.to_bits(),
        "scale diverged: {ss} vs {sc} (n={}, {}, {norm:?})",
        v.len(),
        grid.label()
    );
    for i in 0..v.len() {
        prop_assert!(
            simd[i] == scalar[i],
            "level {i} diverged: {} vs {} (x={:e}, n={}, {}, {norm:?})",
            simd[i],
            scalar[i],
            v[i],
            v.len(),
            grid.label()
        );
    }
    Ok(())
}

#[test]
fn prop_simd_levels_bit_identical_to_scalar_per_grid() {
    forall("simd-vs-scalar-levels", 250, 3000, |g| {
        let n = g.usize_in(0, g.size);
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let norm = common::gen_norm(g);
        let mut words = vec![0u8; n * 4];
        g.rng.fill_bytes(&mut words);
        assert_bucket_identical(&v, &words, &grid, norm)
    });
}

#[test]
fn prop_uniform_entry_point_matches_scalar() {
    // The uniform fast entry (`quantize_bucket_into`) directly, including
    // large s values the grid generator does not emit.
    forall("simd-vs-scalar-uniform", 150, 3000, |g| {
        let n = g.usize_in(0, g.size);
        let v = common::gen_vec(g, n);
        let s = [1u32, 7, 255, 65535][g.usize_in(0, 3)];
        let norm = common::gen_norm(g);
        let mut words = vec![0u8; n * 4];
        g.rng.fill_bytes(&mut words);
        let mut simd = vec![0i32; n];
        let mut scalar = vec![0i32; n];
        let ss = stochastic::quantize_bucket_into(&v, &words, s, norm, &mut simd);
        let sc = stochastic::quantize_bucket_into_scalar(&v, &words, s, norm, &mut scalar);
        prop_assert!(ss.to_bits() == sc.to_bits(), "scale diverged (s={s})");
        prop_assert!(simd == scalar, "levels diverged (n={n}, s={s}, {norm:?})");
        Ok(())
    });
}

#[test]
fn every_tail_length_and_adversarial_fill() {
    // Deterministic sweep of lengths around the 8-lane boundary, with the
    // bucket made *entirely* of adversarial values (the property test only
    // sprinkles them).
    let adv = common::ADVERSARIAL_VALUES;
    let mut r = Xoshiro256::from_u64(77);
    for n in 0..=40usize {
        let v: Vec<f32> = (0..n).map(|i| adv[(i * 5 + n) % adv.len()]).collect();
        let mut words = vec![0u8; n * 4];
        r.fill_bytes(&mut words);
        for grid in [
            LevelGrid::uniform(1),
            LevelGrid::uniform(255),
            LevelGrid::exponential(1),
            LevelGrid::exponential(7),
            LevelGrid::exponential(127),
            LevelGrid::custom(vec![1.0]).unwrap(),
            LevelGrid::custom(vec![0.03, 0.2, 0.21, 0.9, 1.0]).unwrap(),
        ] {
            for norm in [Norm::L2, Norm::Max] {
                assert_bucket_identical(&v, &words, &grid, norm).unwrap();
            }
        }
    }
}

#[test]
fn nan_and_inf_inputs_stay_identical() {
    // NaN/±inf coordinates are outside the quantizer's contract but must
    // still be deterministic and identical across the two implementations
    // (the scalar semantics — NaN rides the min() clamp — are frozen).
    let mut r = Xoshiro256::from_u64(78);
    let v = [
        f32::NAN,
        -f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        1.0,
        -0.5,
        0.0,
        -0.0,
        3e38,
        1e-45,
        f32::NAN,
    ];
    let mut words = vec![0u8; v.len() * 4];
    for _ in 0..50 {
        r.fill_bytes(&mut words);
        for grid in [LevelGrid::uniform(7), LevelGrid::exponential(4)] {
            for norm in [Norm::L2, Norm::Max] {
                assert_bucket_identical(&v, &words, &grid, norm).unwrap();
            }
        }
    }
}

#[test]
fn all_zero_and_degenerate_scale_buckets() {
    let mut r = Xoshiro256::from_u64(79);
    let cases: Vec<Vec<f32>> = vec![
        vec![0.0; 19],
        vec![-0.0; 8],
        vec![1e-45, 0.0, -1e-45, 0.0, 1e-45, -0.0, 0.0, 1e-45, 0.0],
        vec![3e38; 17], // L2 scale overflows to inf ⇒ degenerate
    ];
    for v in &cases {
        let mut words = vec![0u8; v.len() * 4];
        r.fill_bytes(&mut words);
        for grid in [
            LevelGrid::uniform(7),
            LevelGrid::exponential(4),
            LevelGrid::custom(vec![0.5, 1.0]).unwrap(),
        ] {
            for norm in [Norm::L2, Norm::Max] {
                assert_bucket_identical(v, &words, &grid, norm).unwrap();
            }
        }
    }
}

#[test]
fn prop_full_pipeline_wire_bytes_unchanged_by_simd() {
    // End-to-end: the SIMD quantizer feeds the fused encoder; the frames it
    // emits must decode to quantized gradients whose levels equal a
    // reconstruction from the scalar oracle run bucket-by-bucket over the
    // same RNG stream.
    forall("simd-wire-equivalence", 60, 2000, |g| {
        let (n, bucket) = common::gen_dims(g);
        let v = common::gen_vec(g, n);
        let grid = common::gen_grid(g);
        let seed = common::gen_seed(g);
        let mut qrng = Xoshiro256::from_u64(seed);
        let q = stochastic::quantize_grid(&v, &grid, bucket, Norm::Max, &mut qrng);
        // scalar replay of the same RNG stream (one fill_bytes per bucket)
        let mut rng = Xoshiro256::from_u64(seed);
        let chunk = bucket.min(v.len()).max(1);
        let mut words = vec![0u8; chunk * 4];
        for (bi, c) in v.chunks(bucket).enumerate() {
            let w = &mut words[..c.len() * 4];
            rng.fill_bytes(w);
            let mut lv = vec![0i32; c.len()];
            let sc = stochastic::quantize_bucket_into_grid_scalar(c, w, &grid, Norm::Max, &mut lv);
            prop_assert!(
                q.buckets[bi].scale.to_bits() == sc.to_bits(),
                "bucket {bi} scale diverged"
            );
            prop_assert!(q.buckets[bi].levels == lv, "bucket {bi} levels diverged");
        }
        Ok(())
    });
}
