//! Serial vs pipelined recompressing-ring exchange at K=4 over loopback
//! TCP: the measured counterpart to the §5 overlap *model*. Four ranks of
//! this process connect a real mesh; rank 0's exchange step is timed once
//! with the hop-serial path and once with `with_pipelining(true)` (per-peer
//! writer threads ship hop h's frame while the main thread decodes and
//! re-encodes hop h+1 — same bits, overlapped wall clock).
//!
//! Loopback transfer is cheap relative to the codec, so the win here is
//! modest by construction; what this bench pins is the *regression
//! direction*: pipelining must never cost wall clock. A hard in-bench
//! assert fails the run if the pipelined median exceeds 1.05× the serial
//! median, and the committed baseline envelope in
//! `rust/benches/baselines/pipeline_overlap.json` lets the advisory perf
//! lane catch order-of-magnitude drift.
//!
//! Run: `cargo bench --bench pipeline_overlap`.

use std::time::Duration;

use qsgd::bench::{section, Bench, Report};
use qsgd::config::CollectiveSpec;
use qsgd::coordinator::CompressorSpec;
use qsgd::transport::{Endpoint, Mesh, MeshConfig, SocketExchange};
use qsgd::util::rng::{self, Xoshiro256};
use qsgd::util::stats;

const WORLD: usize = 4;
const N: usize = 1 << 18;
const SEED: u64 = 7;

fn free_tcp_endpoint() -> Endpoint {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("probe socket");
    Endpoint::Tcp(l.local_addr().expect("probe addr").to_string())
}

fn mesh_cfg(rank: usize) -> MeshConfig {
    MeshConfig {
        rank,
        world: WORLD,
        io_timeout: Duration::from_secs(30),
        connect_timeout: Duration::from_secs(30),
    }
}

/// Time rank 0's K=4 recompressing-ring step with every rank in the given
/// mode; returns the median step wall in seconds. Peers loop exchanges
/// until rank 0 drops its mesh out from under them (the same teardown the
/// loopback bench uses — the next hop errors and the thread exits).
fn bench_ring(b: &Bench, report: &mut Report, pipelined: bool) -> f64 {
    let base = free_tcp_endpoint();
    let spec = CollectiveSpec::ring();
    let comp = CompressorSpec::qsgd_4bit();
    let mode = if pipelined { "pipelined" } else { "serial" };

    let mut peers = Vec::new();
    for rank in 1..WORLD {
        let base = base.clone();
        let spec = spec.clone();
        let comp = comp.clone();
        peers.push(std::thread::spawn(move || {
            let mesh = Mesh::connect(&base, &mesh_cfg(rank)).expect("peer mesh");
            let mut ex = SocketExchange::new(&spec, comp.codec(), mesh, SEED)
                .expect("peer exchange")
                .with_pipelining(pipelined)
                .expect("peer pipelining");
            let grad = rng::normal_vec(&mut Xoshiro256::stream(5, rank as u64), N);
            let mut mean = Vec::new();
            while ex.exchange(&grad, &mut mean).is_ok() {}
        }));
    }

    let mesh = Mesh::connect(&base, &mesh_cfg(0)).expect("rank 0 mesh");
    let mut ex = SocketExchange::new(&spec, comp.codec(), mesh, SEED)
        .expect("rank 0 exchange")
        .with_pipelining(pipelined)
        .expect("rank 0 pipelining");
    let grad = rng::normal_vec(&mut Xoshiro256::stream(5, 0), N);
    let mut mean = Vec::new();
    let s = b.run(&format!("ring recompress K=4 ({mode})"), || {
        ex.exchange(&grad, &mut mean).expect("exchange").wire.payload_bytes
    });
    s.report();
    report.add("ring_k4", &s, Some(N as f64));

    // One instrumented step: where rank 0's wall actually went. Pipelining
    // should move seconds out of the io-blocked bucket.
    let st = ex.exchange(&grad, &mut mean).expect("instrumented step");
    let occ = &st.occupancy;
    println!(
        "  {mode} occupancy: io-blocked {}, codec {}, idle {} (of {})",
        stats::fmt_duration(occ.io_blocked_s),
        stats::fmt_duration(occ.codec_s),
        stats::fmt_duration(occ.idle_s),
        stats::fmt_duration(occ.total_s()),
    );
    report.add_metric("occupancy", &format!("{mode} io_blocked_s"), occ.io_blocked_s);
    report.add_metric("occupancy", &format!("{mode} codec_s"), occ.codec_s);
    report.add_metric("occupancy", &format!("{mode} idle_s"), occ.idle_s);

    drop(ex);
    for p in peers {
        p.join().expect("peer thread");
    }
    s.median()
}

fn main() {
    let b = Bench::quick();
    let mut report = Report::new("pipeline_overlap");

    section("recompressing ring @K=4 (tcp loopback): serial vs pipelined");
    let serial = bench_ring(&b, &mut report, false);
    let pipelined = bench_ring(&b, &mut report, true);
    let ratio = pipelined / serial.max(f64::MIN_POSITIVE);
    println!(
        "\n  serial {} vs pipelined {} per step — {:.2}x",
        stats::fmt_duration(serial),
        stats::fmt_duration(pipelined),
        ratio,
    );
    report.add_metric("summary", "serial_median_s", serial);
    report.add_metric("summary", "pipelined_median_s", pipelined);
    report.add_metric("summary", "pipelined_over_serial", ratio);
    report.write("BENCH_pipeline_overlap.json").expect("write bench json");

    // Hard floor on the feature's value: pipelining may be a wash on a fast
    // loopback, but it must never *cost* wall clock. (Written after the
    // report so a failing run still leaves the artifact for debugging.)
    assert!(
        ratio <= 1.05,
        "pipelined ring step ({pipelined:.6}s) slower than 1.05x serial ({serial:.6}s): \
         {ratio:.3}x — the writer-thread path is costing wall clock"
    );
}
