//! Socket-transport loopback benchmarks: framing overhead in memory, framed
//! round trips over real loopback sockets (TCP and, on unix, UDS), and a
//! full end-to-end `SocketExchange` all-to-all step at K=2 — the measured
//! counterpart to the α–β *modeled* exchange times in
//! `BENCH_collectives_exchange.json`.
//!
//! Loopback numbers are kernel- and scheduler-dependent, so the committed
//! baseline envelope in `rust/benches/baselines/transport_loopback.json` is
//! deliberately loose: the advisory perf lane catches order-of-magnitude
//! regressions (a lost buffer reuse, an accidental per-hop allocation, a
//! dropped TCP_NODELAY), not microsecond drift.
//!
//! Run: `cargo bench --bench transport_loopback`.

use std::time::Duration;

use qsgd::bench::{section, Bench, Report};
use qsgd::config::CollectiveSpec;
use qsgd::coordinator::CompressorSpec;
use qsgd::transport::{write_frame, Endpoint, FrameReader, Mesh, MeshConfig, SocketExchange};
use qsgd::util::rng::{self, Xoshiro256};
use qsgd::util::stats;

fn free_tcp_endpoint() -> Endpoint {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("probe socket");
    Endpoint::Tcp(l.local_addr().expect("probe addr").to_string())
}

fn pair_cfg(rank: usize) -> MeshConfig {
    MeshConfig {
        rank,
        world: 2,
        io_timeout: Duration::from_secs(30),
        connect_timeout: Duration::from_secs(30),
    }
}

/// Connect a 2-rank mesh across two threads of this process and hand both
/// ends back.
fn mesh_pair(base: &Endpoint) -> (Mesh, Mesh) {
    let b2 = base.clone();
    let peer = std::thread::spawn(move || Mesh::connect(&b2, &pair_cfg(1)).expect("rank 1 mesh"));
    let m0 = Mesh::connect(base, &pair_cfg(0)).expect("rank 0 mesh");
    (m0, peer.join().expect("rank 1 thread"))
}

/// Time 1 MiB framed round trips on rank 0 while a peer thread echoes until
/// the socket closes under it.
fn bench_round_trip(b: &Bench, report: &mut Report, label: &str, base: &Endpoint) {
    const MSG: usize = 1 << 20;
    let (mut m0, mut m1) = mesh_pair(base);
    let peer = std::thread::spawn(move || {
        let payload = vec![0x5Au8; MSG];
        while m1.send_recv(0, 0, &payload).is_ok() {}
    });
    let payload = vec![0xA5u8; MSG];
    let s = b.run(&format!("send_recv 1MiB round trip ({label})"), || {
        m0.send_recv(1, 1, &payload).expect("round trip").len()
    });
    s.report_throughput(2.0 * MSG as f64); // both directions cross the socket
    report.add("round_trip", &s, Some(MSG as f64));
    drop(m0); // closes the stream; the peer's next hop errors out
    peer.join().expect("peer thread");
}

fn main() {
    let b = Bench::quick();
    let mut report = Report::new("transport_loopback");

    // -- framing in memory: reusable-buffer write + chunked reassembly ------
    section("length-prefixed framing (in memory)");
    {
        const MSG: usize = 1 << 20;
        let payload = vec![0x5Au8; MSG];
        let mut wire: Vec<u8> = Vec::with_capacity(MSG + 8);
        let mut reader = FrameReader::new();
        let s = b.run("frame 1MiB write+read", || {
            wire.clear();
            write_frame(&mut wire, &payload).expect("write");
            let mut cur = std::io::Cursor::new(&wire[..]);
            reader.read_frame(&mut cur).expect("read").expect("frame").len()
        });
        s.report_throughput(MSG as f64);
        report.add("framing", &s, Some(MSG as f64));
    }

    // -- framed round trips over real loopback sockets ----------------------
    section("framed round trips over loopback sockets");
    bench_round_trip(&b, &mut report, "tcp", &free_tcp_endpoint());
    #[cfg(unix)]
    {
        let path = std::env::temp_dir().join(format!("qsgd-bench-{}.sock", std::process::id()));
        let base = Endpoint::Uds(path.clone());
        bench_round_trip(&b, &mut report, "uds", &base);
        qsgd::transport::net::cleanup_uds(&path, 2);
    }

    // -- end-to-end quantized exchange step at K=2 --------------------------
    section("SocketExchange all-to-all step @K=2 (tcp loopback)");
    {
        let n = 1usize << 18;
        let spec = CollectiveSpec::AllToAll;
        let comp = CompressorSpec::qsgd_4bit();
        let (m0, m1) = mesh_pair(&free_tcp_endpoint());
        let spec1 = spec.clone();
        let comp1 = comp.clone();
        let peer = std::thread::spawn(move || {
            let mut ex = SocketExchange::new(&spec1, comp1.codec(), m1, 7).expect("rank 1");
            let grad = rng::normal_vec(&mut Xoshiro256::stream(5, 1), n);
            let mut mean = Vec::new();
            while ex.exchange(&grad, &mut mean).is_ok() {}
        });
        let mut ex = SocketExchange::new(&spec, comp.codec(), m0, 7).expect("rank 0");
        let grad = rng::normal_vec(&mut Xoshiro256::stream(5, 0), n);
        let mut mean = Vec::new();
        let s = b.run(&format!("exchange {} {} K=2", spec.label(), comp.label()), || {
            ex.exchange(&grad, &mut mean).expect("exchange").wire.payload_bytes
        });
        s.report();
        report.add("exchange", &s, Some(n as f64));

        // one more instrumented step for the measured phase split
        let st = ex.exchange(&grad, &mut mean).expect("instrumented step");
        println!(
            "  wall split: encode {}, transfer {}, decode {}; {} outbound payload",
            stats::fmt_duration(st.wall.encode_s),
            stats::fmt_duration(st.wall.transfer_s),
            stats::fmt_duration(st.wall.decode_s),
            stats::fmt_bytes(st.wire.payload_bytes as f64),
        );
        report.add_metric("exchange", "a2a k2 encode_s", st.wall.encode_s);
        report.add_metric("exchange", "a2a k2 transfer_s", st.wall.transfer_s);
        report.add_metric("exchange", "a2a k2 decode_s", st.wall.decode_s);
        report.add_metric("exchange", "a2a k2 payload_bytes", st.wire.payload_bytes as f64);
        drop(ex);
        peer.join().expect("peer thread");
    }

    report.write("BENCH_transport_loopback.json").expect("write bench json");
}
