//! Collective-exchange benchmarks: per-algorithm wall time, simulated
//! bytes-per-worker, simulated epoch time on the paper's 16-GPU AlexNet
//! testbed, and the zero-steady-state-allocation invariant of the ring's
//! hop re-encode path.
//!
//! Hard assertions (this bench doubles as the perf-lane enforcement of the
//! subsystem's acceptance bar):
//!   * ring allreduce at K=16 moves strictly fewer simulated bytes per
//!     worker than all-to-all for the same `CompressorSpec`;
//!   * the ring hop re-encode path performs zero steady-state heap
//!     allocations (uniform-grid arm).
//!
//! Results land in `BENCH_collectives_exchange.json` (schema 1, like
//! `BENCH_coding_hotpath.json`); CI uploads the file as an artifact and
//! compares timed sections against the committed baseline in
//! `rust/benches/baselines/`.
//!
//! Run: `cargo bench --bench collectives_exchange` (pin `QSGD_THREADS` for
//! reproducible parallel sections).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use qsgd::bench::{section, Bench, Report};
use qsgd::collectives;
use qsgd::config::CollectiveSpec;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::coordinator::CompressorSpec;
use qsgd::models::{zoo, CostModel};
use qsgd::simnet::{Link, Preset, SimNet, Topology};
use qsgd::util::rng::{self, Xoshiro256};
use qsgd::util::stats;

struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn main() {
    let b = Bench::quick();
    let mut report = Report::new("collectives_exchange");

    let k = 16usize;
    let n = 1usize << 19; // ~0.5M coords ≈ a mid-size model shard
    let coords = n as f64;
    let spec = CompressorSpec::qsgd_4bit();
    let net = SimNet::new(k, Link::new(3.5e9, 50e-6), Topology::P2pBroadcast);
    let grads: Vec<Vec<f32>> = (0..k)
        .map(|w| {
            let mut r = Xoshiro256::stream(5, w as u64);
            rng::normal_vec(&mut r, n)
        })
        .collect();

    let algos = [
        CollectiveSpec::AllToAll,
        CollectiveSpec::ring(),
        CollectiveSpec::ring_ef(),
        CollectiveSpec::hierarchical(4),
    ];

    // -- wall time + simulated traffic per algorithm ------------------------
    section(&format!("collective exchange @K={k}, {} (1 step)", spec.label()));
    let mut bytes_per_worker = Vec::new();
    for col in &algos {
        let mut algo = collectives::build(col, spec.codec(), k, 7);
        algo.prepare(n);
        let mut mean = Vec::new();
        // one warm exchange so scratch and buffers are steady-state
        let x0 = algo.exchange(&net, &grads, &mut mean).expect("exchange");
        let s = b.run(&format!("exchange {}", col.label()), || {
            algo.exchange(&net, &grads, &mut mean).expect("exchange").hops
        });
        s.report();
        report.add("exchange", &s, Some(coords));
        let bpw = x0.wire.payload_bytes as f64 / k as f64;
        println!(
            "  {:<9} bytes/worker {:>10}, sim transfer {:>9}, hops {:>2}, recompressions {}",
            col.label(),
            stats::fmt_bytes(bpw),
            stats::fmt_duration(x0.time.secs()),
            x0.hops,
            x0.recompressions,
        );
        report.add_metric("traffic", &format!("{} bytes_per_worker", col.label()), bpw);
        report.add_metric(
            "traffic",
            &format!("{} sim_transfer_s", col.label()),
            x0.time.secs(),
        );
        report.add_metric(
            "traffic",
            &format!("{} recompress_err_sq", col.label()),
            x0.recompress_err_sq,
        );
        bytes_per_worker.push((col.label(), bpw));
    }
    let a2a_bpw = bytes_per_worker[0].1;
    let ring_bpw = bytes_per_worker[1].1;
    assert!(
        ring_bpw < a2a_bpw,
        "ACCEPTANCE: ring must move strictly fewer bytes/worker than all-to-all \
         (ring {ring_bpw} vs a2a {a2a_bpw})"
    );
    report.add_metric("traffic", "ring_vs_a2a_bytes_ratio", ring_bpw / a2a_bpw);

    // -- zero-alloc steady state of the hop re-encode path ------------------
    section("ring hop re-encode: steady-state allocations (tentpole invariant)");
    {
        let mut algo = collectives::build(&CollectiveSpec::ring(), spec.codec(), k, 11);
        algo.prepare(n);
        let mut mean = Vec::new();
        for _ in 0..2 {
            algo.exchange(&net, &grads, &mut mean).expect("warmup");
        }
        let before = alloc_count();
        algo.exchange(&net, &grads, &mut mean).expect("steady");
        let allocs = alloc_count() - before;
        println!("  allocations in one steady-state ring exchange: {allocs}");
        assert_eq!(allocs, 0, "ring hop re-encode path must be allocation-free");
        report.add_metric("alloc", "ring_steady_state_allocs", allocs as f64);
    }

    // -- simulated epoch time per algorithm (paper testbed) -----------------
    section("simulated AlexNet epoch @16 GPUs (K80-PCIe) per collective");
    {
        let alexnet = zoo::alexnet();
        let simnet = SimNet::preset(16, Preset::K80Pcie);
        let cost = CostModel::k80();
        let fp = simulate_epoch(&alexnet, 16, &EpochArm::fp32(), &simnet, &cost, 1, 0);
        println!(
            "  {:<22} epoch {:>9}  comm {:>3.0}%",
            "32bit a2a",
            stats::fmt_duration(fp.epoch_time()),
            fp.breakdown.comm_fraction() * 100.0
        );
        report.add_metric("epoch_sim", "fp32 a2a epoch_s", fp.epoch_time());
        for col in &algos {
            let arm = EpochArm::qsgd(4, 512).with_collective(col.clone());
            let r = simulate_epoch(&alexnet, 16, &arm, &simnet, &cost, 1, 0);
            println!(
                "  {:<22} epoch {:>9}  comm {:>3.0}%  B/wkr {:>10}  speedup {:.2}x",
                format!("QSGD 4bit {}", col.label()),
                stats::fmt_duration(r.epoch_time()),
                r.breakdown.comm_fraction() * 100.0,
                stats::fmt_bytes(r.bytes_per_worker),
                fp.epoch_time() / r.epoch_time()
            );
            report.add_metric(
                "epoch_sim",
                &format!("qsgd4 {} epoch_s", col.label()),
                r.epoch_time(),
            );
            report.add_metric(
                "epoch_sim",
                &format!("qsgd4 {} bytes_per_worker", col.label()),
                r.bytes_per_worker,
            );
        }
    }

    report.write("BENCH_collectives_exchange.json").expect("write bench json");
}
