//! Table 1 reproduction: per-network end-to-end speedup of QSGD over the
//! 32-bit baseline on 8 simulated GPUs (2 for the LSTM, as in the paper),
//! with the paper's reported value printed alongside.
//!
//! Bytes-on-wire come from the real Rust encoder on tensor-shaped synthetic
//! gradients; times from the calibrated K80/PCIe simulator (DESIGN.md
//! §Substitutions).
//!
//! Run: `cargo bench --bench table1_speedup`

use qsgd::bench::section;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::metrics::Table;
use qsgd::models::{zoo, CostModel};
use qsgd::simnet::{Preset, SimNet};
use qsgd::util::stats;

fn main() {
    section("Table 1: end-to-end speedup vs 32-bit (K80/PCIe preset)");
    let cost = CostModel::k80();

    // (network, paper bits arm, gpus, paper speedup, note)
    let rows: Vec<(zoo::NetworkShape, u32, usize, f64, &str)> = vec![
        (zoo::alexnet(), 4, 8, 2.05, ""),
        (zoo::resnet152(), 8, 8, 1.56, ""),
        (zoo::resnet50(), 4, 8, 1.26, ""),
        (zoo::resnet110_cifar(), 4, 8, 1.10, ""),
        (zoo::bn_inception(), 4, 8, 1.16, "paper: projected"),
        (zoo::vgg19(), 4, 8, 2.25, "paper: projected"),
        (zoo::lstm_an4(), 4, 2, 2.0, "2 GPUs"),
    ];

    let mut t = Table::new(&[
        "Network", "Params", "GPUs", "Arm", "32bit epoch", "QSGD epoch", "Speedup", "Paper", "Note",
    ]);
    for (net, bits, gpus, paper, note) in rows {
        let simnet = SimNet::preset(gpus, Preset::K80Pcie);
        let bucket = if bits <= 2 { 64 } else { 512 };
        let fp = simulate_epoch(&net, gpus, &EpochArm::fp32(), &simnet, &cost, 2, 0);
        let q = simulate_epoch(&net, gpus, &EpochArm::qsgd(bits, bucket), &simnet, &cost, 2, 0);
        let speedup = fp.epoch_time() / q.epoch_time();
        t.row(&[
            net.name.to_string(),
            format!("{:.0}M", net.params() as f64 / 1e6),
            gpus.to_string(),
            format!("{bits}bit/{bucket}"),
            stats::fmt_duration(fp.epoch_time()),
            stats::fmt_duration(q.epoch_time()),
            format!("{speedup:.2}x"),
            format!("{paper:.2}x"),
            note.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nShape check: communication-intensive nets (AlexNet, VGG, LSTM) gain most;\n\
         computation-intensive nets (Inception, ResNet) gain least; nothing regresses.\n\
         Absolute factors depend on the interconnect calibration (EXPERIMENTS.md §T1)."
    );

    section("Ablation: what a ring-allreduce fp32 baseline would change");
    let mut t = Table::new(&["Network", "QSGD vs naive-MPI fp32", "QSGD vs ring fp32"]);
    for net in [zoo::alexnet(), zoo::resnet50()] {
        let simnet = SimNet::preset(8, Preset::K80Pcie);
        let fp = simulate_epoch(&net, 8, &EpochArm::fp32(), &simnet, &cost, 1, 0);
        let ring = simulate_epoch(&net, 8, &EpochArm::fp32_allreduce(), &simnet, &cost, 1, 0);
        let q = simulate_epoch(&net, 8, &EpochArm::qsgd(4, 512), &simnet, &cost, 1, 0);
        t.row(&[
            net.name.to_string(),
            format!("{:.2}x", fp.epoch_time() / q.epoch_time()),
            format!("{:.2}x", ring.epoch_time() / q.epoch_time()),
        ]);
    }
    t.print();
    println!("  (the paper's §6 notes MPI lacked sparse/variable types — a modern\n   collective stack shrinks, but does not erase, QSGD's advantage)");
}
