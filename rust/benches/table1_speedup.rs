//! Table 1 reproduction: per-network end-to-end speedup of QSGD over the
//! 32-bit baseline on 8 simulated GPUs (2 for the LSTM, as in the paper),
//! with the paper's reported value printed alongside.
//!
//! Bytes-on-wire come from the real Rust encoder on tensor-shaped synthetic
//! gradients; times from the calibrated K80/PCIe simulator (DESIGN.md
//! §Substitutions).
//!
//! Run: `cargo bench --bench table1_speedup`

use qsgd::bench::section;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::metrics::Table;
use qsgd::models::{zoo, CostModel};
use qsgd::simnet::{Link, Preset, SimNet, Topology};
use qsgd::util::{json, stats};

/// (network, paper bits arm, gpus, paper speedup, note)
fn paper_rows() -> Vec<(zoo::NetworkShape, u32, usize, f64, &'static str)> {
    vec![
        (zoo::alexnet(), 4, 8, 2.05, ""),
        (zoo::resnet152(), 8, 8, 1.56, ""),
        (zoo::resnet50(), 4, 8, 1.26, ""),
        (zoo::resnet110_cifar(), 4, 8, 1.10, ""),
        (zoo::bn_inception(), 4, 8, 1.16, "paper: projected"),
        (zoo::vgg19(), 4, 8, 2.25, "paper: projected"),
        (zoo::lstm_an4(), 4, 2, 2.0, "2 GPUs"),
    ]
}

/// Fit an α–β [`Link`] from the committed loopback-bench medians. Framing
/// rows cross the wire once; round-trip rows are two symmetric messages, so
/// one message is half the median. Exchange rows are skipped — they fold
/// codec time into the wall and would bias the bandwidth low. Returns
/// `None` when the baseline file is missing, unparseable, or yields no
/// usable samples.
fn measured_link(path: &str) -> Option<(Link, usize)> {
    let src = std::fs::read_to_string(path).ok()?;
    let doc = json::parse(&src).ok()?;
    let mut samples: Vec<(usize, f64)> = Vec::new();
    for r in doc.get("results")?.as_arr()? {
        let section = r.get("section").and_then(|s| s.as_str()).unwrap_or("");
        let bytes = r.get("coords").and_then(|c| c.as_usize()).unwrap_or(0);
        let secs = r.get("median_ns").and_then(|m| m.as_f64()).unwrap_or(0.0) * 1e-9;
        if bytes == 0 || secs <= 0.0 {
            continue;
        }
        match section {
            "framing" => samples.push((bytes, secs)),
            "round_trip" => samples.push((bytes, secs / 2.0)),
            _ => {}
        }
    }
    if samples.is_empty() {
        return None;
    }
    Some((Link::fit(&samples), samples.len()))
}

fn main() {
    section("Table 1: end-to-end speedup vs 32-bit (K80/PCIe preset)");
    let cost = CostModel::k80();

    let rows = paper_rows();

    let mut t = Table::new(&[
        "Network", "Params", "GPUs", "Arm", "32bit epoch", "QSGD epoch", "Speedup", "Paper", "Note",
    ]);
    for (net, bits, gpus, paper, note) in rows {
        let simnet = SimNet::preset(gpus, Preset::K80Pcie);
        let bucket = if bits <= 2 { 64 } else { 512 };
        let fp = simulate_epoch(&net, gpus, &EpochArm::fp32(), &simnet, &cost, 2, 0);
        let q = simulate_epoch(&net, gpus, &EpochArm::qsgd(bits, bucket), &simnet, &cost, 2, 0);
        let speedup = fp.epoch_time() / q.epoch_time();
        t.row(&[
            net.name.to_string(),
            format!("{:.0}M", net.params() as f64 / 1e6),
            gpus.to_string(),
            format!("{bits}bit/{bucket}"),
            stats::fmt_duration(fp.epoch_time()),
            stats::fmt_duration(q.epoch_time()),
            format!("{speedup:.2}x"),
            format!("{paper:.2}x"),
            note.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nShape check: communication-intensive nets (AlexNet, VGG, LSTM) gain most;\n\
         computation-intensive nets (Inception, ResNet) gain least; nothing regresses.\n\
         Absolute factors depend on the interconnect calibration (EXPERIMENTS.md §T1)."
    );

    // Same table, but the interconnect is *measured*, not a preset: α and β
    // least-squares-fitted from the committed transport_loopback medians
    // (this machine's real framing + socket round-trip wall clock).
    section("Table 1 on the measured loopback link (α–β fit from bench medians)");
    let baseline =
        concat!(env!("CARGO_MANIFEST_DIR"), "/benches/baselines/transport_loopback.json");
    match measured_link(baseline) {
        None => println!(
            "  no usable samples in {baseline};\n  \
             run `cargo bench --bench transport_loopback` to refresh the baseline"
        ),
        Some((link, n)) => {
            println!(
                "  fitted from {n} medians: α = {:.1} µs, bandwidth = {}/s",
                link.latency_s * 1e6,
                stats::fmt_bytes(link.bandwidth_bps)
            );
            let mut t =
                Table::new(&["Network", "GPUs", "Arm", "modeled", "measured", "Paper"]);
            for (net, bits, gpus, paper, _) in paper_rows() {
                let bucket = if bits <= 2 { 64 } else { 512 };
                let speedup = |simnet: &SimNet| {
                    let fp = simulate_epoch(&net, gpus, &EpochArm::fp32(), simnet, &cost, 2, 0);
                    let q = simulate_epoch(
                        &net,
                        gpus,
                        &EpochArm::qsgd(bits, bucket),
                        simnet,
                        &cost,
                        2,
                        0,
                    );
                    fp.epoch_time() / q.epoch_time()
                };
                let modeled = speedup(&SimNet::preset(gpus, Preset::K80Pcie));
                let measured = speedup(&SimNet::new(gpus, link, Topology::P2pBroadcast));
                t.row(&[
                    net.name.to_string(),
                    gpus.to_string(),
                    format!("{bits}bit/{bucket}"),
                    format!("{modeled:.2}x"),
                    format!("{measured:.2}x"),
                    format!("{paper:.2}x"),
                ]);
            }
            t.print();
            println!(
                "  (loopback is far faster than the paper's 10 GbE-era PCIe fabric, so the\n   \
                 measured column compresses toward 1x — the *ordering* across networks is\n   \
                 the invariant to check)"
            );
        }
    }

    section("Ablation: what a ring-allreduce fp32 baseline would change");
    let mut t = Table::new(&["Network", "QSGD vs naive-MPI fp32", "QSGD vs ring fp32"]);
    for net in [zoo::alexnet(), zoo::resnet50()] {
        let simnet = SimNet::preset(8, Preset::K80Pcie);
        let fp = simulate_epoch(&net, 8, &EpochArm::fp32(), &simnet, &cost, 1, 0);
        let ring = simulate_epoch(&net, 8, &EpochArm::fp32_allreduce(), &simnet, &cost, 1, 0);
        let q = simulate_epoch(&net, 8, &EpochArm::qsgd(4, 512), &simnet, &cost, 1, 0);
        t.row(&[
            net.name.to_string(),
            format!("{:.2}x", fp.epoch_time() / q.epoch_time()),
            format!("{:.2}x", ring.epoch_time() / q.epoch_time()),
        ]);
    }
    t.print();
    println!("  (the paper's §6 notes MPI lacked sparse/variable types — a modern\n   collective stack shrinks, but does not erase, QSGD's advantage)");
}
