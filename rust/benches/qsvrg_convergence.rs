//! Theorem 3.6 reproduction: QSVRG linear convergence and bits-per-epoch.
//!
//! Regenerates: (a) the per-epoch optimality gap of QSVRG vs exact parallel
//! SVRG vs the 0.9^p reference rate; (b) the communication budget vs the
//! (F + 2.8n)(T+1) + Fn bound; (c) a plain-QSGD contrast arm showing why
//! variance reduction changes the convergence class.
//!
//! Run: `cargo bench --bench qsvrg_convergence`

use qsgd::bench::section;
use qsgd::coordinator::sources::ConvexSource;
use qsgd::coordinator::svrg::{self, SvrgConfig};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::{LogisticProblem, Objective};
use qsgd::metrics::Table;
use qsgd::util::stats;

fn main() -> anyhow::Result<()> {
    let epochs = 10usize;
    let processors = 4usize;
    let obj = LogisticProblem::generate(512, 128, 0.02, 0);
    let kappa = obj.smoothness() / obj.strong_convexity();
    let f_star = svrg::solve_f_star(&obj, 8000);

    section(&format!(
        "QSVRG vs SVRG: m=512, n=128, κ≈{kappa:.1}, K={processors}, f*≈{f_star:.6}"
    ));
    let mk = |quantize| SvrgConfig { processors, epochs, iters: None, eta: None, seed: 1, quantize };
    let rq = svrg::run(&mk(true), &obj, f_star)?;
    let re = svrg::run(&mk(false), &obj, f_star)?;

    let mut t = Table::new(&["epoch", "QSVRG gap", "exact SVRG gap", "0.9^p (Thm 3.6)"]);
    let g0 = rq.gap.points[0].1;
    for e in 0..=epochs {
        t.row(&[
            e.to_string(),
            format!("{:.3e}", rq.gap.points[e].1),
            format!("{:.3e}", re.gap.points[e].1),
            format!("{:.3e}", g0 * 0.9f64.powi(e as i32)),
        ]);
    }
    t.print();
    let rate =
        (rq.gap.last().unwrap() / g0).powf(1.0 / epochs as f64);
    println!("\nQSVRG per-epoch contraction: {rate:.3} (Theorem 3.6 guarantees ≤ 0.9)");

    section("bits per processor per epoch (Theorem 3.6 budget)");
    let measured =
        rq.wire.payload_bytes as f64 * 8.0 / (processors as f64 * epochs as f64);
    println!(
        "measured: {:.0} bits ({}) — bound (F+2.8n)(T+1)+Fn: {:.0} bits ({})",
        measured,
        stats::fmt_bytes(measured / 8.0),
        rq.bits_bound_per_epoch,
        stats::fmt_bytes(rq.bits_bound_per_epoch / 8.0),
    );
    println!(
        "bits/coordinate on quantized updates: {:.2} (fp32 = 32)",
        rq.wire.bits_per_coordinate()
    );

    section("contrast: plain QSGD (no variance reduction) on the same objective");
    // Plain SGD has a variance floor at constant step size; SVRG does not.
    let p = LogisticProblem::generate(512, 128, 0.02, 0);
    let mut src = ConvexSource::new(p, 4, 2);
    let mut cfg = SyncConfig::quick(processors, 600, CompressorSpec::qsgd_4bit(), 0.05);
    cfg.log_every = 100;
    let res = SyncTrainer::new(cfg).run(&mut src)?;
    let qsgd_gap = res.loss.tail_mean(2) - f_star;
    println!(
        "plain QSGD gap after 600 steps: {qsgd_gap:.3e} vs QSVRG after {epochs} epochs: {:.3e}",
        rq.gap.last().unwrap()
    );
    println!("(linear vs sublinear convergence — the point of §3.3)");
    Ok(())
}
