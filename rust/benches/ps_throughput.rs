//! Parameter-server service benchmarks: per-coordinate push decode-add and
//! pull re-encode service times on a single shard, then a sustained
//! in-process heavy-traffic run (Zipf clients, mixed push/pull, bursty
//! open-loop arrivals) reported as msgs/sec with p50/p99 service-latency
//! percentiles from the server's own metrics.
//!
//! The throughput row is the repo's first *higher-is-better* bench result:
//! it is emitted via `Report::add_rate`, carries `"direction": "higher"`,
//! and the regression check inverts its ratio accordingly — the committed
//! baseline is a conservative floor, not a ceiling.
//!
//! Run: `cargo bench --bench ps_throughput`.

use std::sync::Arc;

use qsgd::bench::{section, Bench, Report};
use qsgd::coordinator::CompressorSpec;
use qsgd::ps::{run_traffic, Service, ServiceConfig, ShardMap, Target, TrafficConfig};
use qsgd::util::rng::{self, Xoshiro256};
use qsgd::util::stats;

/// Headline shape: 256Ki coordinates across 4 shards (64Ki per shard, 128
/// QSGD buckets each at the paper's 512 bucket size).
const DIM: usize = 1 << 18;
const SHARDS: usize = 4;

fn service(queue_depth: usize) -> Service {
    let cfg = ServiceConfig {
        compressor: CompressorSpec::qsgd_4bit(),
        lr: 0.05,
        seed: 11,
        staleness: None,
        queue_depth,
    };
    Service::new(ShardMap::uniform(DIM, SHARDS).unwrap(), &cfg)
}

fn main() {
    let b = Bench::quick();
    let mut report = Report::new("ps_throughput");
    let shard_len = DIM / SHARDS;

    // -- single-shard service paths ----------------------------------------
    section("shard service paths (64Ki-coord shard, qsgd 4bit/512)");
    {
        let svc = service(64);
        let codec = svc.codec().clone();
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(3), shard_len);
        let frame = codec.session(Xoshiro256::from_u64(4)).compress(&grad);

        // Push: fused decode-add straight into the shard slice. Repeated
        // application of one frame drifts the parameters, which is fine —
        // decode cost depends on the frame, not the accumulator values.
        let s = b.run("push decode-add 64Ki-coord shard", || {
            svc.push(0, u64::MAX, &frame).expect("push")
        });
        s.report();
        report.add("push", &s, Some(shard_len as f64));

        // Pull: versioned-snapshot re-encode through a per-connection
        // session (version is stable here, so the snapshot copy is paid
        // once and the steady state measures pure encode).
        let mut sess = codec.session(Xoshiro256::from_u64(5));
        let mut out = Vec::new();
        let s = b.run("pull re-encode 64Ki-coord shard", || {
            svc.pull_encoded(1, sess.as_mut(), &mut out).expect("pull");
            out.len()
        });
        s.report();
        report.add("pull", &s, Some(shard_len as f64));
        report.add_metric("pull", "encoded frame bytes", frame.len() as f64);
    }

    // -- sustained heavy-traffic run ---------------------------------------
    section("heavy traffic (in-process, 16 clients / 4 threads, zipf 1.0)");
    {
        let svc = Arc::new(service(256));
        let tcfg = TrafficConfig {
            clients: 16,
            threads: 4,
            ops: 20_000,
            push_fraction: 0.8,
            zipf: 1.0,
            burst: 16,
            seed: 2,
        };
        let rep = run_traffic(&svc, Target::InProcess, &tcfg).expect("traffic run");
        // Op conservation is a hard invariant, not a perf number: every op
        // must have drawn exactly one response.
        assert_eq!(rep.ops, tcfg.ops as u64, "traffic run dropped ops");
        assert_eq!(
            rep.pushed_ok + rep.pulls_ok + rep.stale + rep.shed,
            rep.ops,
            "op accounting does not conserve"
        );
        println!("{}", rep.summary());
        let m = svc.metrics();
        println!("service: {}", m.summary());

        report.add_rate("traffic", "sustained msgs/sec", rep.msgs_per_sec());
        report.add_metric("traffic", "push-decode p50 ns", m.push_decode.p50_ns());
        report.add_metric("traffic", "push-decode p99 ns", m.push_decode.p99_ns());
        report.add_metric("traffic", "pull-encode p99 ns", m.pull_encode.p99_ns());
        report.add_metric("traffic", "shed responses", m.shed as f64);
        report.add_metric("traffic", "stale rejections", m.stale_rejected as f64);
        println!(
            "push-decode p99 {}  pull-encode p99 {}",
            stats::fmt_duration(m.push_decode.p99_ns() / 1e9),
            stats::fmt_duration(m.pull_encode.p99_ns() / 1e9),
        );
    }

    report.write("BENCH_ps_throughput.json").expect("write bench json");
}
