//! L3 hot-path microbenchmarks: quantization (SIMD vs scalar oracle), Elias
//! coding, end-to-end encode/decode throughput, the fused zero-allocation
//! pipeline vs the two-phase oracle (single-thread and 8-worker parallel),
//! and intra-message parallel decode over directory-bearing frames. These
//! numbers feed `CostModel` calibration and the §Perf log in EXPERIMENTS.md.
//!
//! A counting global allocator verifies the tentpole invariant: the fused
//! encode loop performs **zero** steady-state heap allocations (directory
//! emission included).
//!
//! Every section is recorded into `BENCH_coding_hotpath.json`
//! (median/p10/p90 ns, ns/coord, alloc counts) so the perf trajectory is
//! machine-readable across PRs; CI uploads it as an artifact and compares
//! `ns_per_coord` against the committed baseline.
//!
//! Run: `cargo bench --bench coding_hotpath` (pin `QSGD_THREADS` for
//! reproducible parallel sections).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use qsgd::bench::{section, Bench, Report, Sampled};
use qsgd::coding::gradient::{self, Regime};
use qsgd::coding::FusedEncoder;
use qsgd::coordinator::CompressorSpec;
use qsgd::quant::{stochastic, Codec, EncodeSession, LevelGrid, Norm};
use qsgd::util::par;
use qsgd::util::rng::{self, Xoshiro256};
use rand_core::RngCore;

/// Counts every allocation and reallocation (frees are not interesting for
/// the zero-alloc steady-state check).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn main() {
    let b = Bench::default();
    let mut report = Report::new("coding_hotpath");
    let mut rng = Xoshiro256::from_u64(0);
    let n = 1 << 20; // 1M coordinates ≈ a mid-size model shard
    let grad = rng::normal_vec(&mut rng, n);
    let coords = n as f64;

    section("quantize (1M coords)");
    for (label, s, bucket, norm) in [
        ("4-bit/512 max-norm (paper §5)", 7u32, 512usize, Norm::Max),
        ("2-bit/64 max-norm", 1, 64, Norm::Max),
        ("8-bit/512 max-norm", 127, 512, Norm::Max),
        ("s=√n L2 (paper §3.1)", 1024, n, Norm::L2),
    ] {
        let mut r = Xoshiro256::from_u64(1);
        let s1 = b.run(&format!("quantize {label}"), || {
            stochastic::quantize(&grad, s, bucket, norm, &mut r)
        });
        s1.report_throughput(coords * 4.0);
        report.add("quantize", &s1, Some(coords));
    }

    section("SIMD level assignment vs scalar oracle (1M coords, tentpole)");
    {
        let bucket = 512usize;
        let mut words = vec![0u8; bucket * 4];
        let mut levels = vec![0i32; bucket];
        let mut r = Xoshiro256::from_u64(11);
        // identical RNG consumption in both variants ⇒ identical work
        let mut run_grid = |name: &str, grid: &LevelGrid, simd: bool| -> Sampled {
            let sampled = b.run(name, || {
                let mut nz = 0i64;
                for c in grad.chunks(bucket) {
                    let wds = &mut words[..c.len() * 4];
                    r.fill_bytes(wds);
                    let lv = &mut levels[..c.len()];
                    let scale = if simd {
                        stochastic::quantize_bucket_into_grid(c, wds, grid, Norm::Max, lv)
                    } else {
                        stochastic::quantize_bucket_into_grid_scalar(c, wds, grid, Norm::Max, lv)
                    };
                    nz += lv.iter().filter(|&&l| l != 0).count() as i64 + scale as i64;
                }
                nz
            });
            sampled.report_throughput(coords * 4.0);
            sampled
        };
        let uni_simd = run_grid("uniform s=7 SIMD (8-lane)", &LevelGrid::uniform(7), true);
        let uni_scalar = run_grid("uniform s=7 scalar oracle", &LevelGrid::uniform(7), false);
        let exp = LevelGrid::exponential(7);
        let exp_simd = run_grid("nuqsgd s=7 exponent fast path", &exp, true);
        let exp_scalar = run_grid("nuqsgd s=7 partition_point oracle", &exp, false);
        let uni_speedup = uni_scalar.median() / uni_simd.median();
        let exp_speedup = exp_scalar.median() / exp_simd.median();
        println!("  uniform SIMD vs scalar: {uni_speedup:.2}x");
        println!("  exponential fast path vs binary search: {exp_speedup:.2}x");
        for s in [&uni_simd, &uni_scalar, &exp_simd, &exp_scalar] {
            report.add("simd_levels", s, Some(coords));
        }
        report.add_metric("simd_levels", "uniform_simd_speedup", uni_speedup);
        report.add_metric("simd_levels", "exponential_fastpath_speedup", exp_speedup);
    }

    section("entropy code (quantized 4-bit/512, 1M coords)");
    let mut r = Xoshiro256::from_u64(2);
    let q = stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r);
    let enc_sparse = b.run("encode sparse", || gradient::encode(&q, Regime::Sparse));
    enc_sparse.report_throughput(coords * 4.0);
    report.add("entropy_code", &enc_sparse, Some(coords));
    let enc_dense = b.run("encode dense", || gradient::encode(&q, Regime::Dense));
    enc_dense.report_throughput(coords * 4.0);
    report.add("entropy_code", &enc_dense, Some(coords));
    let bytes_sparse = gradient::encode(&q, Regime::Sparse);
    let bytes_dense = gradient::encode(&q, Regime::Dense);
    println!(
        "  (wire: sparse {} vs dense {} for {} coords)",
        bytes_sparse.len(),
        bytes_dense.len(),
        n
    );
    report.add_metric("entropy_code", "sparse_wire_bytes", bytes_sparse.len() as f64);
    report.add_metric("entropy_code", "dense_wire_bytes", bytes_dense.len() as f64);
    let dec = b.run("decode sparse", || gradient::decode(&bytes_sparse).unwrap());
    dec.report_throughput(coords * 4.0);
    report.add("entropy_code", &dec, Some(coords));
    let dec2 = b.run("decode dense", || gradient::decode(&bytes_dense).unwrap());
    dec2.report_throughput(coords * 4.0);
    report.add("entropy_code", &dec2, Some(coords));

    section("intra-message parallel decode (1M coords, directory frame)");
    {
        // at 1M coords / 512-bucket the default rule emits the directory
        assert_eq!(bytes_dense[1] >> 4, gradient::FRAME_VERSION_DIR as u8);
        let mut serial_acc = vec![0.0f32; n];
        gradient::decode_add(&bytes_dense, 0.125, &mut serial_acc).unwrap();
        // one reused accumulator: the timed body is fill + decode, never an
        // allocation, so ns/coord tracks the decoder rather than the heap
        let mut acc = vec![0.0f32; n];
        let s_serial = b.run("decode_add serial (dense 4-bit/512)", || {
            acc.fill(0.0);
            gradient::decode_add(&bytes_dense, 0.125, &mut acc).unwrap();
            (acc[0], acc[n - 1])
        });
        s_serial.report_throughput(coords * 4.0);
        report.add("intra_decode", &s_serial, Some(coords));
        for threads in [2usize, 4, 8] {
            let s_par = b.run(&format!("par_decode_add {threads} threads"), || {
                acc.fill(0.0);
                gradient::par_decode_add_threads(&bytes_dense, 0.125, &mut acc, threads).unwrap();
                (acc[0], acc[n - 1])
            });
            s_par.report_throughput(coords * 4.0);
            report.add("intra_decode", &s_par, Some(coords));
            let speedup = s_serial.median() / s_par.median();
            println!("  par_decode_add x{threads} vs serial: {speedup:.2}x");
            report.add_metric("intra_decode", &format!("speedup_{threads}t"), speedup);
            // and it is bit-identical to the serial walk
            acc.fill(0.0);
            gradient::par_decode_add_threads(&bytes_dense, 0.125, &mut acc, threads).unwrap();
            assert_eq!(acc, serial_acc, "parallel decode diverged at {threads} threads");
        }
    }

    section("fused pipeline (tentpole): zero-alloc encode vs two-phase");
    let spec = CompressorSpec::qsgd_4bit();
    let mut two_phase = spec.codec_two_phase().session(Xoshiro256::from_u64(5));
    let s_two = b.run("two-phase compress 4-bit/512", || two_phase.compress(&grad));
    s_two.report_throughput(coords * 4.0);
    report.add("fused_pipeline", &s_two, Some(coords));

    let mut fused = FusedEncoder::new(7, 512, Norm::Max, None);
    fused.reserve(n); // pre-size the bitstream: zero allocs from call one
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut r = Xoshiro256::from_u64(5);
    let s_fused = b.run("fused encode_into 4-bit/512", || {
        fused.encode_into(&grad, &mut r, &mut out);
        out.len()
    });
    s_fused.report_throughput(coords * 4.0);
    report.add("fused_pipeline", &s_fused, Some(coords));
    println!(
        "  fused vs two-phase, single thread: {:.2}x",
        s_two.median() / s_fused.median()
    );
    report.add_metric("fused_pipeline", "fused_speedup", s_two.median() / s_fused.median());

    // Zero-allocation steady state: one warm call sizes the level/word
    // scratch (and the directory staging buffer), then a measured window
    // must not touch the heap at all.
    fused.encode_into(&grad, &mut r, &mut out);
    let before = alloc_count();
    for _ in 0..16 {
        fused.encode_into(&grad, &mut r, &mut out);
    }
    let allocs = alloc_count() - before;
    println!("  steady-state heap allocations over 16 fused encodes: {allocs} (must be 0)");
    report.add_metric("fused_pipeline", "steady_state_allocs", allocs as f64);
    assert_eq!(allocs, 0, "fused encode loop must not allocate in steady state");

    section("NUQSGD (exponential grid) through the fused pipeline");
    let nu_spec = CompressorSpec::nuqsgd_4bit();
    let mut nu_two = nu_spec.codec_two_phase().session(Xoshiro256::from_u64(6));
    let s_nu_two = b.run("two-phase NUQSGD 4-bit/512", || nu_two.compress(&grad));
    s_nu_two.report_throughput(coords * 4.0);
    report.add("nuqsgd", &s_nu_two, Some(coords));
    let mut nu_fused = FusedEncoder::with_grid(LevelGrid::exponential(7), 512, Norm::Max, None);
    nu_fused.reserve(n * 2);
    let mut nu_out: Vec<u8> = Vec::with_capacity(n * 2);
    let mut r = Xoshiro256::from_u64(6);
    let s_nu_fused = b.run("fused NUQSGD encode_into 4-bit/512", || {
        nu_fused.encode_into(&grad, &mut r, &mut nu_out);
        nu_out.len()
    });
    s_nu_fused.report_throughput(coords * 4.0);
    report.add("nuqsgd", &s_nu_fused, Some(coords));
    println!(
        "  NUQSGD fused vs two-phase, single thread: {:.2}x",
        s_nu_two.median() / s_nu_fused.median()
    );
    // Bit-identity on the wire, same seeds.
    {
        let mut a = nu_spec.codec_two_phase().session(Xoshiro256::from_u64(7));
        let mut c = nu_spec.codec().session(Xoshiro256::from_u64(7));
        assert_eq!(
            a.compress(&grad),
            c.compress(&grad),
            "NUQSGD fused wire bytes diverged from two-phase"
        );
    }
    // Zero-allocation steady state for the non-uniform grid path too: the
    // grid's point table is Arc-shared scratch, so the fused loop must stay
    // off the heap exactly like the uniform path.
    nu_fused.encode_into(&grad, &mut r, &mut nu_out);
    let before = alloc_count();
    for _ in 0..16 {
        nu_fused.encode_into(&grad, &mut r, &mut nu_out);
    }
    let allocs = alloc_count() - before;
    println!("  steady-state heap allocations over 16 fused NUQSGD encodes: {allocs} (must be 0)");
    report.add_metric("nuqsgd", "steady_state_allocs", allocs as f64);
    assert_eq!(allocs, 0, "fused NUQSGD encode loop must not allocate in steady state");

    section("8-worker parallel encode (acceptance: ≥2x vs sequential two-phase)");
    const K: usize = 8;
    struct Lane {
        sess: Box<dyn EncodeSession>,
    }
    let mk_lanes = |two_phase: bool| -> Vec<Lane> {
        let codec = if two_phase { spec.codec_two_phase() } else { spec.codec() };
        (0..K)
            .map(|w| Lane { sess: codec.session(Xoshiro256::stream(99, w as u64)) })
            .collect()
    };
    let mut seq_lanes = mk_lanes(true);
    let s_seq = b.run("sequential two-phase x8", || {
        let mut total = 0usize;
        for lane in seq_lanes.iter_mut() {
            total += lane.sess.compress(&grad).len();
        }
        total
    });
    s_seq.report_throughput(coords * 4.0 * K as f64);
    report.add("par_encode", &s_seq, Some(coords * K as f64));
    let mut par_lanes = mk_lanes(false);
    let s_par = b.run("parallel fused x8 (scoped pool)", || {
        par::par_map_mut(&mut par_lanes, |_, lane| lane.sess.compress(&grad).len())
            .iter()
            .sum::<usize>()
    });
    s_par.report_throughput(coords * 4.0 * K as f64);
    report.add("par_encode", &s_par, Some(coords * K as f64));
    let speedup = s_seq.median() / s_par.median();
    println!("  parallel fused x8 vs sequential two-phase x8: {speedup:.2}x (target ≥2x)");
    report.add_metric("par_encode", "speedup_x8", speedup);
    // Same seeds ⇒ the two paths must also agree byte-for-byte.
    let mut a = mk_lanes(true);
    let mut c = mk_lanes(false);
    for (la, lc) in a.iter_mut().zip(c.iter_mut()) {
        assert_eq!(
            la.sess.compress(&grad),
            lc.sess.compress(&grad),
            "fused wire bytes diverged from two-phase"
        );
    }

    section("end-to-end codec (quantize+code / decode+dequant)");
    for spec in [
        CompressorSpec::qsgd_2bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::nuqsgd_4bit(),
        CompressorSpec::OneBit { column: 512 },
        CompressorSpec::TernGrad { bucket: 512 },
    ] {
        let codec = spec.codec();
        let mut sess = codec.session(Xoshiro256::from_u64(3));
        let enc = b.run(&format!("compress {}", spec.label()), || sess.compress(&grad));
        enc.report_throughput(coords * 4.0);
        report.add("end_to_end", &enc, Some(coords));
        let msg = sess.compress(&grad);
        let dec = b.run(&format!("decompress {}", spec.label()), || {
            codec.decode(&msg, n).unwrap()
        });
        dec.report_throughput(coords * 4.0);
        report.add("end_to_end", &dec, Some(coords));
    }

    section("decode-side aggregation (K=8 peers)");
    let mut r = Xoshiro256::from_u64(4);
    let qs: Vec<_> =
        (0..8).map(|_| stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r)).collect();
    let agg = b.run("dequantize_add x8 (decoded)", || {
        let mut acc = vec![0.0f32; n];
        for q in &qs {
            q.dequantize_add(1.0 / 8.0, &mut acc);
        }
        acc
    });
    agg.report_throughput(coords * 4.0 * 8.0);
    report.add("aggregation", &agg, Some(coords * 8.0));
    // Fused wire→accumulator path (§6 sparsity exploitation): sparse s=1
    // messages aggregate in O(nnz) per peer.
    let sparse_msgs: Vec<Vec<u8>> = (0..8)
        .map(|_| {
            let q = stochastic::quantize(&grad, 1, n, Norm::L2, &mut r);
            gradient::encode(&q, Regime::Sparse)
        })
        .collect();
    let agg2 = b.run("decode_add x8 (sparse s=1, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &sparse_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg2.report_throughput(coords * 4.0 * 8.0);
    report.add("aggregation", &agg2, Some(coords * 8.0));
    let dense_msgs: Vec<Vec<u8>> = qs.iter().map(gradient::encode_auto).collect();
    let agg3 = b.run("decode_add x8 (4-bit/512, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &dense_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg3.report_throughput(coords * 4.0 * 8.0);
    report.add("aggregation", &agg3, Some(coords * 8.0));
    // Both levels of decode parallelism: message groups on the pool, and
    // each directory-bearing frame's buckets under the leftover budget.
    let threads = par::max_threads();
    let agg4 = b.run("par_decode_mean x8 (4-bit/512)", || {
        qsgd::collectives::par_decode_mean(&dense_msgs, n, 1.0 / 8.0, threads, |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t).map(|_| ())
        })
        .unwrap()
    });
    agg4.report_throughput(coords * 4.0 * 8.0);
    report.add("aggregation", &agg4, Some(coords * 8.0));
    report.add_metric(
        "aggregation",
        "par_decode_mean_speedup_vs_serial",
        agg3.median() / agg4.median(),
    );

    report.write("BENCH_coding_hotpath.json").expect("bench report must be writable");
}
