//! L3 hot-path microbenchmarks: quantization, Elias coding, end-to-end
//! encode/decode throughput. These numbers feed `CostModel` calibration and
//! the §Perf log in EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench coding_hotpath`

use qsgd::bench::{section, Bench};
use qsgd::coding::gradient::{self, Regime};
use qsgd::coordinator::CompressorSpec;
use qsgd::quant::{stochastic, Norm};
use qsgd::util::rng::{self, Xoshiro256};

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::from_u64(0);
    let n = 1 << 20; // 1M coordinates ≈ a mid-size model shard
    let grad = rng::normal_vec(&mut rng, n);
    let coords = n as f64;

    section("quantize (1M coords)");
    for (label, s, bucket, norm) in [
        ("4-bit/512 max-norm (paper §5)", 7u32, 512usize, Norm::Max),
        ("2-bit/64 max-norm", 1, 64, Norm::Max),
        ("8-bit/512 max-norm", 127, 512, Norm::Max),
        ("s=√n L2 (paper §3.1)", 1024, n, Norm::L2),
    ] {
        let mut r = Xoshiro256::from_u64(1);
        let s1 = b.run(&format!("quantize {label}"), || {
            stochastic::quantize(&grad, s, bucket, norm, &mut r)
        });
        s1.report_throughput(coords * 4.0);
    }

    section("entropy code (quantized 4-bit/512, 1M coords)");
    let mut r = Xoshiro256::from_u64(2);
    let q = stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r);
    let enc_sparse = b.run("encode sparse", || gradient::encode(&q, Regime::Sparse));
    enc_sparse.report_throughput(coords * 4.0);
    let enc_dense = b.run("encode dense", || gradient::encode(&q, Regime::Dense));
    enc_dense.report_throughput(coords * 4.0);
    let bytes_sparse = gradient::encode(&q, Regime::Sparse);
    let bytes_dense = gradient::encode(&q, Regime::Dense);
    println!(
        "  (wire: sparse {} vs dense {} for {} coords)",
        bytes_sparse.len(),
        bytes_dense.len(),
        n
    );
    let dec = b.run("decode sparse", || gradient::decode(&bytes_sparse).unwrap());
    dec.report_throughput(coords * 4.0);
    let dec2 = b.run("decode dense", || gradient::decode(&bytes_dense).unwrap());
    dec2.report_throughput(coords * 4.0);

    section("end-to-end Compressor (quantize+code / decode+dequant)");
    for spec in [
        CompressorSpec::qsgd_2bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::OneBit { column: 512 },
        CompressorSpec::TernGrad { bucket: 512 },
    ] {
        let mut c = spec.build(n);
        let mut r = Xoshiro256::from_u64(3);
        let enc = b.run(&format!("compress {}", spec.label()), || c.compress(&grad, &mut r));
        enc.report_throughput(coords * 4.0);
        let msg = c.compress(&grad, &mut r);
        let dec = b.run(&format!("decompress {}", spec.label()), || {
            c.decompress(&msg, n).unwrap()
        });
        dec.report_throughput(coords * 4.0);
    }

    section("decode-side aggregation (K=8 peers)");
    let mut r = Xoshiro256::from_u64(4);
    let qs: Vec<_> =
        (0..8).map(|_| stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r)).collect();
    let agg = b.run("dequantize_add x8 (decoded)", || {
        let mut acc = vec![0.0f32; n];
        for q in &qs {
            q.dequantize_add(1.0 / 8.0, &mut acc);
        }
        acc
    });
    agg.report_throughput(coords * 4.0 * 8.0);
    // Fused wire→accumulator path (§6 sparsity exploitation): sparse s=1
    // messages aggregate in O(nnz) per peer.
    let sparse_msgs: Vec<Vec<u8>> = (0..8)
        .map(|_| {
            let q = stochastic::quantize(&grad, 1, n, Norm::L2, &mut r);
            gradient::encode(&q, Regime::Sparse)
        })
        .collect();
    let agg2 = b.run("decode_add x8 (sparse s=1, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &sparse_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg2.report_throughput(coords * 4.0 * 8.0);
    let dense_msgs: Vec<Vec<u8>> = qs.iter().map(|q| gradient::encode_auto(q)).collect();
    let agg3 = b.run("decode_add x8 (4-bit/512, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &dense_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg3.report_throughput(coords * 4.0 * 8.0);
}
