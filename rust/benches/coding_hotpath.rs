//! L3 hot-path microbenchmarks: quantization, Elias coding, end-to-end
//! encode/decode throughput, and the fused zero-allocation pipeline vs the
//! two-phase oracle (single-thread and 8-worker parallel). These numbers
//! feed `CostModel` calibration and the §Perf log in EXPERIMENTS.md.
//!
//! A counting global allocator verifies the tentpole invariant: the fused
//! encode loop performs **zero** steady-state heap allocations.
//!
//! Run: `cargo bench --bench coding_hotpath`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use qsgd::bench::{section, Bench};
use qsgd::coding::gradient::{self, Regime};
use qsgd::coding::FusedEncoder;
use qsgd::coordinator::CompressorSpec;
use qsgd::quant::{stochastic, Compressor, LevelGrid, Norm};
use qsgd::util::par;
use qsgd::util::rng::{self, Xoshiro256};

/// Counts every allocation and reallocation (frees are not interesting for
/// the zero-alloc steady-state check).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::from_u64(0);
    let n = 1 << 20; // 1M coordinates ≈ a mid-size model shard
    let grad = rng::normal_vec(&mut rng, n);
    let coords = n as f64;

    section("quantize (1M coords)");
    for (label, s, bucket, norm) in [
        ("4-bit/512 max-norm (paper §5)", 7u32, 512usize, Norm::Max),
        ("2-bit/64 max-norm", 1, 64, Norm::Max),
        ("8-bit/512 max-norm", 127, 512, Norm::Max),
        ("s=√n L2 (paper §3.1)", 1024, n, Norm::L2),
    ] {
        let mut r = Xoshiro256::from_u64(1);
        let s1 = b.run(&format!("quantize {label}"), || {
            stochastic::quantize(&grad, s, bucket, norm, &mut r)
        });
        s1.report_throughput(coords * 4.0);
    }

    section("entropy code (quantized 4-bit/512, 1M coords)");
    let mut r = Xoshiro256::from_u64(2);
    let q = stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r);
    let enc_sparse = b.run("encode sparse", || gradient::encode(&q, Regime::Sparse));
    enc_sparse.report_throughput(coords * 4.0);
    let enc_dense = b.run("encode dense", || gradient::encode(&q, Regime::Dense));
    enc_dense.report_throughput(coords * 4.0);
    let bytes_sparse = gradient::encode(&q, Regime::Sparse);
    let bytes_dense = gradient::encode(&q, Regime::Dense);
    println!(
        "  (wire: sparse {} vs dense {} for {} coords)",
        bytes_sparse.len(),
        bytes_dense.len(),
        n
    );
    let dec = b.run("decode sparse", || gradient::decode(&bytes_sparse).unwrap());
    dec.report_throughput(coords * 4.0);
    let dec2 = b.run("decode dense", || gradient::decode(&bytes_dense).unwrap());
    dec2.report_throughput(coords * 4.0);

    section("fused pipeline (tentpole): zero-alloc encode vs two-phase");
    let spec = CompressorSpec::qsgd_4bit();
    let mut two_phase = spec.build_two_phase(n);
    let mut r = Xoshiro256::from_u64(5);
    let s_two = b.run("two-phase compress 4-bit/512", || two_phase.compress(&grad, &mut r));
    s_two.report_throughput(coords * 4.0);

    let mut fused = FusedEncoder::new(7, 512, Norm::Max, None);
    fused.reserve(n); // pre-size the bitstream: zero allocs from call one
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut r = Xoshiro256::from_u64(5);
    let s_fused = b.run("fused encode_into 4-bit/512", || {
        fused.encode_into(&grad, &mut r, &mut out);
        out.len()
    });
    s_fused.report_throughput(coords * 4.0);
    println!(
        "  fused vs two-phase, single thread: {:.2}x",
        s_two.median() / s_fused.median()
    );

    // Zero-allocation steady state: one warm call sizes the level/word
    // scratch, then a measured window must not touch the heap at all.
    fused.encode_into(&grad, &mut r, &mut out);
    let before = alloc_count();
    for _ in 0..16 {
        fused.encode_into(&grad, &mut r, &mut out);
    }
    let allocs = alloc_count() - before;
    println!("  steady-state heap allocations over 16 fused encodes: {allocs} (must be 0)");
    assert_eq!(allocs, 0, "fused encode loop must not allocate in steady state");

    section("NUQSGD (exponential grid) through the fused pipeline");
    let nu_spec = CompressorSpec::nuqsgd_4bit();
    let mut nu_two = nu_spec.build_two_phase(n);
    let mut r = Xoshiro256::from_u64(6);
    let s_nu_two = b.run("two-phase NUQSGD 4-bit/512", || nu_two.compress(&grad, &mut r));
    s_nu_two.report_throughput(coords * 4.0);
    let mut nu_fused = FusedEncoder::with_grid(LevelGrid::exponential(7), 512, Norm::Max, None);
    nu_fused.reserve(n * 2);
    let mut nu_out: Vec<u8> = Vec::with_capacity(n * 2);
    let mut r = Xoshiro256::from_u64(6);
    let s_nu_fused = b.run("fused NUQSGD encode_into 4-bit/512", || {
        nu_fused.encode_into(&grad, &mut r, &mut nu_out);
        nu_out.len()
    });
    s_nu_fused.report_throughput(coords * 4.0);
    println!(
        "  NUQSGD fused vs two-phase, single thread: {:.2}x",
        s_nu_two.median() / s_nu_fused.median()
    );
    // Bit-identity on the wire, same seeds.
    {
        let mut a = nu_spec.build_two_phase(n);
        let mut c = nu_spec.build(n);
        assert_eq!(
            a.compress(&grad, &mut Xoshiro256::from_u64(7)),
            c.compress(&grad, &mut Xoshiro256::from_u64(7)),
            "NUQSGD fused wire bytes diverged from two-phase"
        );
    }
    // Zero-allocation steady state for the non-uniform grid path too: the
    // grid's point table is Arc-shared scratch, so the fused loop must stay
    // off the heap exactly like the uniform path.
    nu_fused.encode_into(&grad, &mut r, &mut nu_out);
    let before = alloc_count();
    for _ in 0..16 {
        nu_fused.encode_into(&grad, &mut r, &mut nu_out);
    }
    let allocs = alloc_count() - before;
    println!("  steady-state heap allocations over 16 fused NUQSGD encodes: {allocs} (must be 0)");
    assert_eq!(allocs, 0, "fused NUQSGD encode loop must not allocate in steady state");

    section("8-worker parallel encode (acceptance: ≥2x vs sequential two-phase)");
    const K: usize = 8;
    struct Lane {
        c: Box<dyn Compressor>,
        rng: Xoshiro256,
    }
    let mk_lanes = |two_phase: bool| -> Vec<Lane> {
        (0..K)
            .map(|w| Lane {
                c: if two_phase { spec.build_two_phase(n) } else { spec.build(n) },
                rng: Xoshiro256::stream(99, w as u64),
            })
            .collect()
    };
    let mut seq_lanes = mk_lanes(true);
    let s_seq = b.run("sequential two-phase x8", || {
        let mut total = 0usize;
        for lane in seq_lanes.iter_mut() {
            total += lane.c.compress(&grad, &mut lane.rng).len();
        }
        total
    });
    s_seq.report_throughput(coords * 4.0 * K as f64);
    let mut par_lanes = mk_lanes(false);
    let s_par = b.run("parallel fused x8 (scoped pool)", || {
        par::par_map_mut(&mut par_lanes, |_, lane| lane.c.compress(&grad, &mut lane.rng).len())
            .iter()
            .sum::<usize>()
    });
    s_par.report_throughput(coords * 4.0 * K as f64);
    let speedup = s_seq.median() / s_par.median();
    println!("  parallel fused x8 vs sequential two-phase x8: {speedup:.2}x (target ≥2x)");
    // Same seeds ⇒ the two paths must also agree byte-for-byte.
    let mut a = mk_lanes(true);
    let mut c = mk_lanes(false);
    for (la, lc) in a.iter_mut().zip(c.iter_mut()) {
        assert_eq!(
            la.c.compress(&grad, &mut la.rng),
            lc.c.compress(&grad, &mut lc.rng),
            "fused wire bytes diverged from two-phase"
        );
    }

    section("end-to-end Compressor (quantize+code / decode+dequant)");
    for spec in [
        CompressorSpec::qsgd_2bit(),
        CompressorSpec::qsgd_4bit(),
        CompressorSpec::qsgd_8bit(),
        CompressorSpec::nuqsgd_4bit(),
        CompressorSpec::OneBit { column: 512 },
        CompressorSpec::TernGrad { bucket: 512 },
    ] {
        let mut c = spec.build(n);
        let mut r = Xoshiro256::from_u64(3);
        let enc = b.run(&format!("compress {}", spec.label()), || c.compress(&grad, &mut r));
        enc.report_throughput(coords * 4.0);
        let msg = c.compress(&grad, &mut r);
        let dec = b.run(&format!("decompress {}", spec.label()), || {
            c.decompress(&msg, n).unwrap()
        });
        dec.report_throughput(coords * 4.0);
    }

    section("decode-side aggregation (K=8 peers)");
    let mut r = Xoshiro256::from_u64(4);
    let qs: Vec<_> =
        (0..8).map(|_| stochastic::quantize(&grad, 7, 512, Norm::Max, &mut r)).collect();
    let agg = b.run("dequantize_add x8 (decoded)", || {
        let mut acc = vec![0.0f32; n];
        for q in &qs {
            q.dequantize_add(1.0 / 8.0, &mut acc);
        }
        acc
    });
    agg.report_throughput(coords * 4.0 * 8.0);
    // Fused wire→accumulator path (§6 sparsity exploitation): sparse s=1
    // messages aggregate in O(nnz) per peer.
    let sparse_msgs: Vec<Vec<u8>> = (0..8)
        .map(|_| {
            let q = stochastic::quantize(&grad, 1, n, Norm::L2, &mut r);
            gradient::encode(&q, Regime::Sparse)
        })
        .collect();
    let agg2 = b.run("decode_add x8 (sparse s=1, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &sparse_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg2.report_throughput(coords * 4.0 * 8.0);
    let dense_msgs: Vec<Vec<u8>> = qs.iter().map(gradient::encode_auto).collect();
    let agg3 = b.run("decode_add x8 (4-bit/512, from wire)", || {
        let mut acc = vec![0.0f32; n];
        for m in &dense_msgs {
            gradient::decode_add(m, 1.0 / 8.0, &mut acc).unwrap();
        }
        acc
    });
    agg3.report_throughput(coords * 4.0 * 8.0);
    // Parallel grouped decode (collectives::par_decode_mean drives this in
    // the trainer); decode-side parallelism beyond grouping is a ROADMAP
    // open item.
    let agg4 = b.run("par_decode_mean x8 (4-bit/512)", || {
        qsgd::collectives::par_decode_mean(&dense_msgs, n, 1.0 / 8.0, |m, a, acc| {
            gradient::decode_add(m, a, acc).map(|_| ())
        })
        .unwrap()
    });
    agg4.report_throughput(coords * 4.0 * 8.0);
}
