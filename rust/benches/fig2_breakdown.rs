//! Figure 2 / Figure 4 reproduction: epoch-time breakdown (communication vs
//! computation) for every evaluation network on 2/4/8/16 GPUs, under
//! 32-bit, 1BitSGD, QSGD 2-bit/64 and QSGD 4-bit/512 — the same series the
//! paper's stacked bars show.
//!
//! Run: `cargo bench --bench fig2_breakdown`

use qsgd::bench::section;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::metrics::Table;
use qsgd::models::{zoo, CostModel};
use qsgd::simnet::{Preset, SimNet};
use qsgd::util::stats;

fn main() {
    let cost = CostModel::k80();
    let arms: [(&str, EpochArm); 4] = [
        ("32bit", EpochArm::fp32()),
        ("1BitSGD", EpochArm::onebit()),
        ("QSGD 2bit/64", EpochArm::qsgd(2, 64)),
        ("QSGD 4bit/512", EpochArm::qsgd(4, 512)),
    ];

    for net in zoo::table1_networks() {
        section(&format!(
            "{} — {} params, global batches {:?}",
            net.name,
            stats::fmt_bytes(net.params() as f64 * 4.0),
            net.batch_sizes
        ));
        let mut t = Table::new(&[
            "GPUs", "arm", "epoch", "comm", "compute", "comm%", "msg/step",
        ]);
        for gpus in [2usize, 4, 8, 16] {
            let simnet = SimNet::preset(gpus, Preset::K80Pcie);
            for (label, arm) in &arms {
                let s = simulate_epoch(&net, gpus, arm, &simnet, &cost, 1, 0);
                t.row(&[
                    gpus.to_string(),
                    label.to_string(),
                    stats::fmt_duration(s.epoch_time()),
                    stats::fmt_duration(s.breakdown.communication().secs()),
                    stats::fmt_duration(s.breakdown.compute.secs()),
                    format!("{:.0}%", s.breakdown.comm_fraction() * 100.0),
                    stats::fmt_bytes(s.message_bytes as f64),
                ]);
            }
        }
        t.print();
    }

    // §5-style overlap: per-layer bucket readiness from the network layout,
    // epoch time re-derived from the transmission schedule. φ = 0 is the
    // stacked-bar serial total above, bit for bit; φ = 1 is full per-layer
    // overlap (communication hidden behind backprop where possible).
    section("overlapped epoch time (schedule-derived, φ ∈ {0, 0.5, 1})");
    for net in [zoo::alexnet(), zoo::resnet50(), zoo::lstm_an4()] {
        let mut t = Table::new(&["GPUs", "arm", "φ=0 (serial)", "φ=0.5", "φ=1", "hidden@φ=1"]);
        for gpus in [8usize, 16] {
            let simnet = SimNet::preset(gpus, Preset::K80Pcie);
            for (label, arm) in &arms {
                let s = simulate_epoch(&net, gpus, arm, &simnet, &cost, 1, 0);
                let serial = s.epoch_time_overlapped(0.0);
                let full = s.epoch_time_overlapped(1.0);
                t.row(&[
                    gpus.to_string(),
                    label.to_string(),
                    stats::fmt_duration(serial),
                    stats::fmt_duration(s.epoch_time_overlapped(0.5)),
                    stats::fmt_duration(full),
                    format!("{:.0}%", (1.0 - full / serial.max(f64::MIN_POSITIVE)) * 100.0),
                ]);
            }
        }
        println!("{}:", net.name);
        t.print();
    }

    section("paper anchor points");
    let cost = CostModel::k80();
    let a = zoo::alexnet();
    let simnet16 = SimNet::preset(16, Preset::K80Pcie);
    let fp = simulate_epoch(&a, 16, &EpochArm::fp32(), &simnet16, &cost, 1, 0);
    let q4 = simulate_epoch(&a, 16, &EpochArm::qsgd(4, 512), &simnet16, &cost, 1, 0);
    println!(
        "16-GPU AlexNet fp32 comm fraction: {:.0}%   (paper: >80%)",
        fp.breakdown.comm_fraction() * 100.0
    );
    println!(
        "16-GPU AlexNet 4-bit comm-time cut: {:.1}x  (paper: 4x)",
        fp.breakdown.communication().secs() / q4.breakdown.communication().secs()
    );
    println!(
        "16-GPU AlexNet 4-bit epoch-time cut: {:.1}x (paper: 2.5x)",
        fp.epoch_time() / q4.epoch_time()
    );
    let l = zoo::lstm_an4();
    let simnet2 = SimNet::preset(2, Preset::K80Pcie);
    let lfp = simulate_epoch(&l, 2, &EpochArm::fp32(), &simnet2, &cost, 1, 0);
    let lq = simulate_epoch(&l, 2, &EpochArm::qsgd(4, 512), &simnet2, &cost, 1, 0);
    println!(
        "2-GPU LSTM fp32 comm fraction: {:.0}%       (paper: 71%)",
        lfp.breakdown.comm_fraction() * 100.0
    );
    println!(
        "2-GPU LSTM 4-bit comm-time cut: {:.1}x      (paper: 6.8x)",
        lfp.breakdown.communication().secs() / lq.breakdown.communication().secs()
    );
    println!(
        "2-GPU LSTM 4-bit epoch-time cut: {:.1}x     (paper: 2.7x)",
        lfp.epoch_time() / lq.epoch_time()
    );
}
