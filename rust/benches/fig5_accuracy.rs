//! Figure 3 / Figure 5 reproduction: accuracy/loss vs training progress for
//! fp32 vs QSGD {2,4,8}-bit, on real training runs (not simulation):
//!
//! * MLP classifier through the full three-layer stack (PJRT-executed AOT
//!   graph) on synthetic-MNIST — skipped gracefully if artifacts are absent.
//! * Ridge logistic regression (Rust-native) — the convex sanity curve.
//!
//! The paper's claim: 4-bit+ QSGD recovers full-precision accuracy in the
//! same number of epochs; 2-bit with small buckets trails slightly.
//!
//! Run: `cargo bench --bench fig5_accuracy`

use qsgd::bench::section;
use qsgd::coordinator::sources::{ConvexSource, RuntimeSource, Workload};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::{ClassifyData, LogisticProblem};
use qsgd::metrics::Table;
use qsgd::models::layout::QuantPlan;
use qsgd::runtime::Runtime;
use qsgd::util::stats;

fn arms() -> Vec<(&'static str, CompressorSpec)> {
    vec![
        ("32bit", CompressorSpec::Fp32),
        ("QSGD 8bit/512", CompressorSpec::qsgd_8bit()),
        ("QSGD 4bit/512", CompressorSpec::qsgd_4bit()),
        ("QSGD 2bit/64", CompressorSpec::qsgd_2bit()),
        ("1BitSGD", CompressorSpec::OneBit { column: 512 }),
    ]
}

fn main() -> anyhow::Result<()> {
    section("Fig. 5(a-like): MLP on synthetic-MNIST via the full 3-layer stack");
    match Runtime::from_default_dir() {
        Ok(rt) => {
            let art = rt.manifest().get("mlp_grad")?.clone();
            let dim = art.inputs[1].shape[1];
            let batch = art.batch.unwrap_or(64);
            let steps = 120;
            let mut t = Table::new(&[
                "arm", "train loss@end", "held-out loss@end", "bits/coord", "vtime",
            ]);
            for (label, spec) in arms() {
                let mut src = RuntimeSource::new(
                    &rt,
                    "mlp_grad",
                    Workload::Classify { data: ClassifyData::new(dim, 10, 0.6, 1.8, 1), batch },
                )?;
                let mut cfg = SyncConfig::quick(8, steps, spec, 0.15);
                cfg.eval_every = steps / 4;
                cfg.plan = art.layout.as_ref().map(QuantPlan::quantize_all);
                let res = SyncTrainer::new(cfg).run(&mut src)?;
                t.row(&[
                    label.to_string(),
                    format!("{:.4}", res.loss.tail_mean(3)),
                    format!("{:.4}", res.eval.last().unwrap_or(f64::NAN)),
                    format!("{:.2}", res.wire.bits_per_coordinate()),
                    stats::fmt_duration(res.virtual_time(true).secs()),
                ]);
            }
            t.print();
        }
        Err(e) => println!("  [skipped — run `make artifacts`: {e}]"),
    }

    section("Fig. 3(convex): ridge logistic regression, loss vs step");
    let steps = 400;
    let mut t = Table::new(&["arm", "loss@50", "loss@150", "loss@400", "time-to-0.35", "bits/coord"]);
    for (label, spec) in arms() {
        let p = LogisticProblem::generate(2048, 512, 1e-3, 5);
        let mut src = ConvexSource::new(p, 16, 9);
        let mut cfg = SyncConfig::quick(8, steps, spec, 0.4);
        cfg.log_every = 10;
        let res = SyncTrainer::new(cfg).run(&mut src)?;
        let at = |s: usize| {
            res.loss
                .points
                .iter()
                .filter(|&&(st, _)| st <= s)
                .next_back()
                .map(|&(_, v)| format!("{v:.4}"))
                .unwrap_or_default()
        };
        t.row(&[
            label.to_string(),
            at(50),
            at(150),
            at(400),
            res.loss
                .first_step_below(0.35)
                .map(|s| format!("step {s}"))
                .unwrap_or_else(|| "—".into()),
            format!("{:.2}", res.wire.bits_per_coordinate()),
        ]);
    }
    t.print();
    println!(
        "\nShape check (paper Fig. 3/5): 8-bit and 4-bit track the 32-bit curve;\n\
         2-bit/64 trails slightly at equal steps — same ordering as the paper."
    );
    Ok(())
}
