//! Theory-validation bench: regenerates the paper's analytical claims as
//! measured-vs-bound tables.
//!
//! * Lemma 3.1 — unbiasedness / variance / sparsity of Q_s.
//! * Theorem 3.2 — sparse code length vs bound.
//! * Corollary 3.3 — dense code length vs 2.8n + 32 at s = √n.
//! * §4 — the bucket-size/bit-width variance knob (√d/2^b table).
//! * Theorem F.4 — deterministic GD quantizer code length.
//!
//! Run: `cargo bench --bench theory_bounds`

use qsgd::bench::section;
use qsgd::coding::gradient as gcode;
use qsgd::metrics::Table;
use qsgd::quant::{deterministic, stochastic, variance_bound, Norm};
use qsgd::util::rng::{self, Xoshiro256};

fn main() {
    let mut rng = Xoshiro256::from_u64(0);

    section("Lemma 3.1: variance + sparsity of Q_s (n = 16384, 40 trials)");
    let n = 16384usize;
    let v = rng::normal_vec(&mut rng, n);
    let vnorm2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();
    let mut t = Table::new(&[
        "s", "E var / ‖v‖²", "min(n/s²,√n/s)", "E nnz", "s(s+√n)", "mean |bias|",
    ]);
    for s in [1u32, 2, 4, 16, 128] {
        let trials = 40;
        let mut var = 0.0f64;
        let mut nnz = 0usize;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let q = stochastic::quantize_paper(&v, s, &mut rng);
            let d = q.dequantize();
            var += v.iter().zip(&d).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
            nnz += q.nnz();
            for (m, x) in mean.iter_mut().zip(&d) {
                *m += *x as f64 / trials as f64;
            }
        }
        let bias: f64 = mean
            .iter()
            .zip(&v)
            .map(|(m, &x)| (m - x as f64).abs())
            .sum::<f64>()
            / n as f64;
        t.row(&[
            s.to_string(),
            format!("{:.3}", var / trials as f64 / vnorm2),
            format!("{:.3}", ((n as f64) / (s as f64).powi(2)).min((n as f64).sqrt() / s as f64)),
            format!("{:.0}", nnz as f64 / trials as f64),
            format!("{:.0}", s as f64 * (s as f64 + (n as f64).sqrt())),
            format!("{bias:.4}"),
        ]);
    }
    t.print();

    section("Theorem 3.2 / Corollary 3.3: expected code length (bits)");
    let mut t = Table::new(&["n", "s", "regime", "measured", "bound", "bits/coord", "paper headline"]);
    for (n, s) in [(4096usize, 1u32), (4096, 2), (16384, 1), (16384, 4)] {
        let v = rng::normal_vec(&mut rng, n);
        let trials = 25;
        let bits: f64 = (0..trials)
            .map(|_| {
                let q = stochastic::quantize_paper(&v, s, &mut rng);
                gcode::encode(&q, gcode::Regime::Sparse).len() as f64 * 8.0
            })
            .sum::<f64>()
            / trials as f64;
        t.row(&[
            n.to_string(),
            s.to_string(),
            "sparse".into(),
            format!("{bits:.0}"),
            format!("{:.0}", gcode::sparse_bits_bound(n, s)),
            format!("{:.3}", bits / n as f64),
            "√n(log n+O(1)) @ s=1".into(),
        ]);
    }
    for n in [1024usize, 4096, 16384] {
        let s = (n as f64).sqrt() as u32;
        let v = rng::normal_vec(&mut rng, n);
        let trials = 25;
        let bits: f64 = (0..trials)
            .map(|_| {
                let q = stochastic::quantize_paper(&v, s, &mut rng);
                gcode::encode(&q, gcode::Regime::Dense).len() as f64 * 8.0
            })
            .sum::<f64>()
            / trials as f64;
        t.row(&[
            n.to_string(),
            format!("√n={s}"),
            "dense".into(),
            format!("{bits:.0}"),
            format!("{:.0}", gcode::dense_bits_bound(n, s)),
            format!("{:.3}", bits / n as f64),
            format!("2.8n+32 = {:.0}", 2.8 * n as f64 + 32.0),
        ]);
    }
    t.print();
    println!("  (dense measured ≈3.1 bits/coord vs Cor. 3.3 headline 2.8 — the paper's");
    println!("   constant drops o(1) terms; the rigorous Lemma A.6 bound holds.)");

    section("§4 variance knob: bucket size d × bit width b (bound √d/2^b)");
    let mut t = Table::new(&["bucket d", "bits b", "bound min(d/s²,√d/s)", "measured var blowup"]);
    for (d, bits) in [(64usize, 2u32), (128, 2), (512, 4), (8192, 4), (512, 8)] {
        let s = (1u32 << (bits - 1)) - 1;
        let v = rng::normal_vec(&mut rng, d);
        let vn2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();
        let trials = 300;
        let var: f64 = (0..trials)
            .map(|_| {
                let q = stochastic::quantize(&v, s, d, Norm::L2, &mut rng);
                let dd = q.dequantize();
                v.iter().zip(&dd).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>()
            })
            .sum::<f64>()
            / trials as f64;
        t.row(&[
            d.to_string(),
            bits.to_string(),
            format!("{:.3}", variance_bound(d, s)),
            format!("{:.3}", var / vn2),
        ]);
    }
    t.print();
    println!("  (paper example: d=512, 4-bit ⇒ √512/2⁴ ≈ 1.41)");

    section("ablation: integer code choice (omega vs gamma vs delta), bits per gradient");
    // Re-encode the same quantized gradients with each integer code and
    // compare total wire size — the design choice behind the paper's
    // Elias-omega pick (asymptotically optimal) vs the simpler codes.
    use qsgd::coding::bitstream::BitWriter;
    use qsgd::coding::elias;
    let mut t = Table::new(&["config", "omega", "gamma", "delta"]);
    for (n, s, label) in [
        (16384usize, 1u32, "s=1 sparse-ish"),
        (16384, 4, "s=4"),
        (16384, 128, "s=√n dense"),
    ] {
        let v = rng::normal_vec(&mut rng, n);
        let q = stochastic::quantize_paper(&v, s, &mut rng);
        let total = |enc: &dyn Fn(&mut BitWriter, u64)| -> u64 {
            let mut w = BitWriter::new();
            for b in &q.buckets {
                for &l in &b.levels {
                    enc(&mut w, l.unsigned_abs() as u64 + 1);
                    if l != 0 {
                        w.write_bit(l < 0);
                    }
                }
            }
            w.len_bits()
        };
        t.row(&[
            label.to_string(),
            format!("{}", total(&|w, k| elias::encode(w, k))),
            format!("{}", total(&elias::encode_gamma)),
            format!("{}", total(&elias::encode_delta)),
        ]);
    }
    t.print();
    println!("  (gamma wins at tiny levels; omega/delta win as levels grow — the\n   paper's omega choice is the asymptotically safe one)");

    section("Theorem F.4: deterministic GD quantizer code length");
    let mut t = Table::new(&["n", "|I(v)|", "√n", "bits", "√n(log n+1+log e)+32"]);
    for n in [256usize, 1024, 4096, 65536] {
        let v = rng::normal_vec(&mut rng, n);
        let q = deterministic::quantize(&v);
        let bits = q.encode().len() * 8;
        let bound = (n as f64).sqrt() * ((n as f64).log2() + 1.0 + std::f64::consts::E.log2()) + 32.0;
        t.row(&[
            n.to_string(),
            q.indices.len().to_string(),
            format!("{:.1}", (n as f64).sqrt()),
            bits.to_string(),
            format!("{bound:.0}"),
        ]);
    }
    t.print();
}
