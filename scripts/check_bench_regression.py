#!/usr/bin/env python3
"""Compare fresh BENCH_*.json reports against committed baselines.

Two modes:

  # explicit pair (legacy; kept for one-off local use)
  check_bench_regression.py NEW_JSON BASELINE_JSON [--threshold 1.25]

  # discovery: every BENCH_<name>.json under --results-dir is compared
  # against --baseline-dir/<name>.json
  check_bench_regression.py [--results-dir .] \
      [--baseline-dir rust/benches/baselines] [--threshold 1.25]

Matches (section, name) rows between the two reports and flags a regression
when the row's value drifts beyond the threshold factor in the *bad*
direction. A row's value is `ns_per_coord` (falling back to `median_ns`,
then `per_sec` for throughput rows); its direction is the row's
`"direction"` field — the default `"lower"` means smaller is better
(latency) and regression is `new/base > threshold`, while `"higher"` means
bigger is better (msgs/sec, ops/sec) and the ratio inverts to
`base/new > threshold`. The baseline row's direction wins when both sides
carry one. Rows present on only one side are reported but never fail the
check (sections come and go across PRs; a baseline row for a
platform-gated bench section may legitimately be absent from a run).
A *missing baseline file* is a soft skip so the advisory lane stays green
until a baseline is committed from a trusted runner's artifact.

Exit codes:
  0  no regressions (including soft skips)
  1  at least one row regressed beyond the threshold
  2  a results file is missing, unreadable, or malformed — the bench lane
     produced garbage, which must never read as "no regressions"
"""

import argparse
import json
import sys
from pathlib import Path


class BenchFormatError(Exception):
    """A results/baseline file exists but is not a valid bench report."""


def load_rows(path: Path) -> dict:
    """Parse a schema-1 bench report into {(section, name): (value, direction)}.

    `direction` is "lower" (latency-style, the default) or "higher"
    (throughput-style rows emitted with a `per_sec` value).
    """
    try:
        doc = json.loads(path.read_text())
    except OSError as e:
        raise BenchFormatError(f"{path}: unreadable ({e})") from e
    except json.JSONDecodeError as e:
        raise BenchFormatError(f"{path}: invalid JSON ({e})") from e
    if not isinstance(doc, dict):
        raise BenchFormatError(f"{path}: top level is not an object")
    results = doc.get("results", [])
    if not isinstance(results, list):
        raise BenchFormatError(f"{path}: 'results' is not a list")
    rows = {}
    for row in results:
        if not isinstance(row, dict):
            raise BenchFormatError(f"{path}: non-object row in 'results'")
        key = (row.get("section"), row.get("name"))
        value = row.get("ns_per_coord")
        if value is None:
            value = row.get("median_ns")
        if value is None:
            value = row.get("per_sec")
        if value is None:
            continue
        direction = row.get("direction", "lower")
        if direction not in ("lower", "higher"):
            raise BenchFormatError(
                f"{path}: row {key} has unknown direction {direction!r}"
            )
        try:
            rows[key] = (float(value), direction)
        except (TypeError, ValueError) as e:
            raise BenchFormatError(
                f"{path}: row {key} has non-numeric timing {value!r}"
            ) from e
    return rows


def compare(new_json: Path, baseline_json: Path, threshold: float) -> list:
    """Print the row-by-row comparison; return the regressed keys."""
    new = load_rows(new_json)
    base = load_rows(baseline_json)

    regressions = []
    for key, (base_v, base_dir) in sorted(base.items()):
        if base_v <= 0:
            continue
        if key not in new:
            print(f"  [gone]    {key[0]} / {key[1]}")
            continue
        new_v, _new_dir = new[key]
        # The committed baseline owns the row's semantics.
        if base_dir == "higher":
            # Throughput: a drop below the floor regresses; guard the
            # degenerate 0-rate case explicitly (ratio would divide by 0).
            ratio = base_v / new_v if new_v > 0 else float("inf")
            unit = "per_sec"
        else:
            ratio = new_v / base_v
            unit = "ns/coord"
        marker = "REGRESSED" if ratio > threshold else "ok"
        print(f"  [{marker:9}] {key[0]} / {key[1]}: "
              f"{base_v:.3f} -> {new_v:.3f} {unit} ({ratio:.2f}x)")
        if ratio > threshold:
            regressions.append((key, ratio))
    for key in sorted(set(new) - set(base)):
        print(f"  [new]     {key[0]} / {key[1]}")
    return regressions


def check_pair(new_json: Path, baseline_json: Path, threshold: float) -> int:
    if not new_json.exists():
        print(f"results file {new_json} does not exist — the bench lane "
              f"did not produce it.")
        return 2
    if not baseline_json.exists():
        print(f"no baseline at {baseline_json} — skipping comparison.")
        print(f"To seed one, commit this run's {new_json} to that path.")
        return 0
    print(f"{new_json} vs {baseline_json}:")
    try:
        regressions = compare(new_json, baseline_json, threshold)
    except BenchFormatError as e:
        print(f"MALFORMED: {e}")
        return 2
    if regressions:
        print(f"  {len(regressions)} row(s) regressed beyond "
              f"{threshold:.2f}x vs the committed baseline.")
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("new_json", nargs="?", type=Path,
                    help="single results file (pair mode)")
    ap.add_argument("baseline_json", nargs="?", type=Path,
                    help="its baseline (pair mode)")
    ap.add_argument("--results-dir", type=Path, default=Path("."),
                    help="directory to glob BENCH_*.json from (discovery mode)")
    ap.add_argument("--baseline-dir", type=Path,
                    default=Path("rust/benches/baselines"),
                    help="directory of committed <name>.json baselines")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when new/baseline exceeds this factor")
    args = ap.parse_args()

    if args.new_json is not None and args.baseline_json is None:
        ap.error("pair mode needs both NEW_JSON and BASELINE_JSON "
                 "(or neither, for discovery mode)")

    if args.new_json is not None:
        pairs = [(args.new_json, args.baseline_json)]
    else:
        found = sorted(args.results_dir.glob("BENCH_*.json"))
        if not found:
            print(f"no BENCH_*.json under {args.results_dir} — the bench "
                  f"lane produced no results to check.")
            return 2
        pairs = [(p, args.baseline_dir / p.name[len("BENCH_"):]) for p in found]

    worst = 0
    for new_json, baseline_json in pairs:
        worst = max(worst, check_pair(new_json, baseline_json, args.threshold))
    if worst == 0:
        print("\nno regressions beyond threshold.")
    return worst


if __name__ == "__main__":
    sys.exit(main())
