#!/usr/bin/env python3
"""Compare a fresh BENCH_*.json against the committed baseline.

Usage: check_bench_regression.py NEW_JSON BASELINE_JSON [--threshold 1.25]

Matches (section, name) rows between the two reports and fails (exit 1)
when any `ns_per_coord` (falling back to `median_ns`) regresses by more
than the threshold factor. Rows present on only one side are reported but
never fail the check (sections come and go across PRs). A missing baseline
file is a soft skip (exit 0) so the advisory lane stays green until a
baseline is committed from a trusted runner's artifact.
"""

import argparse
import json
import sys
from pathlib import Path


def load_rows(path: Path) -> dict:
    doc = json.loads(path.read_text())
    rows = {}
    for row in doc.get("results", []):
        key = (row.get("section"), row.get("name"))
        value = row.get("ns_per_coord") or row.get("median_ns")
        if value is not None:
            rows[key] = float(value)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new_json", type=Path)
    ap.add_argument("baseline_json", type=Path)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when new/baseline exceeds this factor")
    args = ap.parse_args()

    if not args.baseline_json.exists():
        print(f"no baseline at {args.baseline_json} — skipping comparison.")
        print(f"To seed one, commit this run's {args.new_json} to that path.")
        return 0

    new = load_rows(args.new_json)
    base = load_rows(args.baseline_json)

    regressions = []
    for key, base_v in sorted(base.items()):
        if base_v <= 0:
            continue
        new_v = new.get(key)
        if new_v is None:
            print(f"  [gone]    {key[0]} / {key[1]}")
            continue
        ratio = new_v / base_v
        marker = "REGRESSED" if ratio > args.threshold else "ok"
        print(f"  [{marker:9}] {key[0]} / {key[1]}: "
              f"{base_v:.3f} -> {new_v:.3f} ns/coord ({ratio:.2f}x)")
        if ratio > args.threshold:
            regressions.append((key, ratio))
    for key in sorted(set(new) - set(base)):
        print(f"  [new]     {key[0]} / {key[1]}")

    if regressions:
        print(f"\n{len(regressions)} section(s) regressed beyond "
              f"{args.threshold:.2f}x vs the committed baseline.")
        return 1
    print("\nno regressions beyond threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
