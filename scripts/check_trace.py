#!/usr/bin/env python3
"""Validate observability artifacts emitted under `--trace-out DIR`.

Two file kinds, dispatched by name:

  trace_rank<R>.json    Chrome trace-event JSON: a top-level array of
                        B/E phase events. Per (pid, tid) the stream must
                        have non-decreasing `ts`, and begins/ends must
                        balance as a properly nested stack with matching
                        names. Every event needs `name`/`ph`/`ts`/`pid`/
                        `tid` plus integer `args.rank` and `args.step`.
  events_rank<R>.jsonl  One completed span per line: a JSON object with
                        integer `t_ns`/`dur_ns`/`rank`/`tid`/`step` and a
                        non-empty string `name`; `t_ns` must be
                        non-decreasing within each tid.

Usage:

  # validate every trace/events file under one or more directories
  check_trace.py DIR [DIR ...] [--expect-ranks K]

  # or validate explicit files
  check_trace.py trace_rank0.json events_rank0.jsonl

`--expect-ranks K` additionally requires trace_rank{0..K-1}.json to exist
in each directory argument — the multi-process lanes use it to catch a
rank that silently exited before exporting.

Exit codes:
  0  everything validated
  1  at least one file is malformed or violates an invariant
  2  nothing to validate (no matching files found, or a missing path) —
     an empty run must never read as "traces are fine"
"""

import argparse
import json
import sys
from pathlib import Path


class TraceError(Exception):
    """A trace artifact exists but violates the format invariants."""


def _require_int(obj: dict, key: str, where: str) -> int:
    v = obj.get(key)
    if not isinstance(v, int) or isinstance(v, bool) or v < 0:
        raise TraceError(f"{where}: field {key!r} is {v!r}, want a non-negative int")
    return v


def check_chrome(path: Path) -> int:
    """Validate one Chrome trace file; return the number of events."""
    try:
        doc = json.loads(path.read_text())
    except OSError as e:
        raise TraceError(f"{path}: unreadable ({e})") from e
    except json.JSONDecodeError as e:
        raise TraceError(f"{path}: invalid JSON ({e})") from e
    if not isinstance(doc, list):
        raise TraceError(f"{path}: top level is not an array")

    last_ts = {}   # (pid, tid) -> last ts seen
    stacks = {}    # (pid, tid) -> [open span names]
    for i, ev in enumerate(doc):
        where = f"{path}: event {i}"
        if not isinstance(ev, dict):
            raise TraceError(f"{where}: not an object")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            raise TraceError(f"{where}: name is {name!r}, want a non-empty string")
        ph = ev.get("ph")
        if ph not in ("B", "E"):
            raise TraceError(f"{where}: ph is {ph!r}, want 'B' or 'E'")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) or ts < 0:
            raise TraceError(f"{where}: ts is {ts!r}, want a non-negative number")
        pid = _require_int(ev, "pid", where)
        tid = _require_int(ev, "tid", where)
        args = ev.get("args")
        if not isinstance(args, dict):
            raise TraceError(f"{where}: args is {args!r}, want an object")
        _require_int(args, "rank", where)
        _require_int(args, "step", where)

        key = (pid, tid)
        if ts < last_ts.get(key, 0):
            raise TraceError(
                f"{where}: ts {ts} goes backwards on pid={pid} tid={tid} "
                f"(last was {last_ts[key]})")
        last_ts[key] = ts
        stack = stacks.setdefault(key, [])
        if ph == "B":
            stack.append(name)
        elif not stack:
            raise TraceError(f"{where}: E {name!r} with no open span on tid={tid}")
        elif stack[-1] != name:
            raise TraceError(
                f"{where}: E {name!r} does not close the open span "
                f"{stack[-1]!r} on tid={tid}")
        else:
            stack.pop()
    for (pid, tid), stack in stacks.items():
        if stack:
            raise TraceError(
                f"{path}: pid={pid} tid={tid} ends with unclosed span(s) {stack}")
    return len(doc)


def check_jsonl(path: Path) -> int:
    """Validate one JSONL span log; return the number of spans."""
    try:
        text = path.read_text()
    except OSError as e:
        raise TraceError(f"{path}: unreadable ({e})") from e
    last_t = {}  # tid -> last t_ns seen
    n = 0
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        where = f"{path}: line {i + 1}"
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            raise TraceError(f"{where}: invalid JSON ({e})") from e
        if not isinstance(ev, dict):
            raise TraceError(f"{where}: not an object")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            raise TraceError(f"{where}: name is {name!r}, want a non-empty string")
        t_ns = _require_int(ev, "t_ns", where)
        _require_int(ev, "dur_ns", where)
        _require_int(ev, "rank", where)
        tid = _require_int(ev, "tid", where)
        _require_int(ev, "step", where)
        if t_ns < last_t.get(tid, 0):
            raise TraceError(
                f"{where}: t_ns {t_ns} goes backwards on tid={tid} "
                f"(last was {last_t[tid]})")
        last_t[tid] = t_ns
        n += 1
    return n


def check_file(path: Path) -> None:
    if path.name.endswith(".jsonl"):
        n = check_jsonl(path)
        print(f"  [ok] {path}: {n} span(s)")
    else:
        n = check_chrome(path)
        print(f"  [ok] {path}: {n} event(s)")


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="+", type=Path,
                    help="trace-out directories, or explicit trace/events files")
    ap.add_argument("--expect-ranks", type=int, default=None, metavar="K",
                    help="require trace_rank{0..K-1}.json in each directory")
    args = ap.parse_args()

    files = []
    missing = False
    for p in args.paths:
        if p.is_dir():
            found = sorted(p.glob("trace_rank*.json")) + sorted(p.glob("events_rank*.jsonl"))
            if not found:
                print(f"{p}: no trace_rank*.json or events_rank*.jsonl here")
                missing = True
            if args.expect_ranks is not None:
                for r in range(args.expect_ranks):
                    want = p / f"trace_rank{r}.json"
                    if not want.exists():
                        print(f"{p}: expected {want.name} (rank {r} never exported)")
                        missing = True
            files.extend(found)
        elif p.exists():
            files.append(p)
        else:
            print(f"{p}: does not exist")
            missing = True
    if missing:
        return 2
    if not files:
        print("nothing to validate")
        return 2

    bad = 0
    for f in files:
        try:
            check_file(f)
        except TraceError as e:
            print(f"  [BAD] {e}")
            bad += 1
    if bad:
        print(f"{bad} file(s) failed validation.")
        return 1
    print(f"all {len(files)} trace file(s) valid.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
